"""Single-pass trace-fold engine (THAPI §3.4 analysis, made to scale).

The Babeltrace-style graph (``babeltrace.py``) is the *general* analysis
tier: it materializes every record as an :class:`~repro.core.babeltrace.Event`,
globally time-sorts all streams through the muxer, and dict-ifies every
interval — the right shape for pretty-printing, timelines, and validation,
where callbacks need named fields and cross-stream ordering.  For the tally
monoid none of that is necessary:

  * entry/exit pairing is **(pid, tid)-local** (the interval filter keys its
    stacks by ``(pid, tid, provider:api)``), and each CTF-lite stream holds
    exactly one ``(pid, tid)``'s records in timestamp order — so the global
    ``heapq.merge`` time-sort is provably irrelevant to the folded result;
  * a tally reads **at most two payload fields** per record (span begin/end
    timestamps, plus the kernel name for launch spans) — unpacking the full
    payload tuple per event is wasted work;
  * the fold target is a monoid — no intermediate ``Event``/``Interval``
    objects need to exist at all.

This module is that fast tier: an eid-indexed *fold plan* compiled once per
trace model, executed as a tight single-pass loop over framed record buffers
(one ``memoryview`` per chunk, flat ``[calls, total, min, max]`` list
accumulators instead of per-record object churn, kernel-name row keys memoized
on the raw payload bytes).  It is shared by offline analysis
(:func:`fold_trace`, the default behind ``tally_trace``/``iprof tally``) and
by the live analyzer (:class:`repro.core.online.OnlineAnalyzer` folds drained
ring chunks through the same engine), so the two can never diverge.

Equivalence contract: for any trace, ``fold_trace(d)`` and the legacy graph
(``tally_trace(d, legacy_graph=True)``) produce semantically identical
tallies — same rows, same counts/min/max, same process/thread/hostname sets,
same discarded total (property-tested in ``tests/test_fold.py``, including
compressed streams, truncated tails, unmatched entries, and discard
records).
"""

from __future__ import annotations

import os
import struct
from typing import Dict, List, Optional, Sequence, Tuple

from .api_model import DISCARD_EVENT_ID, FIELD_CLASSES, VARLEN, TraceModel
from .ctf import StreamReader, TraceMeta, load_sidecar, stream_files
from .plugins.tally import ApiStat, Tally, intern_key
from .ringbuffer import RECORD_HEADER, RECORD_HEADER_SIZE

_SPAN_TS = struct.Struct("<QQ")  # ts_begin, ts_end prefix of every span payload
_U64 = struct.Struct("<Q")
_LEN = struct.Struct("<I")  # varlen-field length prefix (see tracepoints codegen)

#: fold-plan opcodes, one per eid (dense dispatch, two list indexes a record)
K_SKIP = 0  # sample / unknown-phase events: nothing a tally reads
K_ENTRY = 1  # push ts on the (pid,tid)-local per-API stack; payload untouched
K_EXIT = 2  # pop + accumulate host row; payload untouched
K_SPAN = 3  # device row from the two leading u64 timestamps
K_SPAN_NAMED = 4  # launch span: row key is the kernel name at a fixed offset
K_DISCARD = 5  # ctf:events_discarded counter
K_SPAN_NAMED_GENERIC = 6  # launch span whose name needs a full payload unpack

#: plan row layout: (kind, key, pair_id, name_off, name_key_cache)
_SKIP_ROW = (K_SKIP, None, -1, 0, None)


def _fixed_offset_of(fields, name: str) -> Optional[int]:
    """Byte offset of a varlen ``str`` field reachable through fixed-size
    predecessors only; None when a varlen field precedes it."""
    off = 0
    for f in fields:
        if f.name == name:
            return off if f.cls == "str" else None
        if f.cls in VARLEN:
            return None
        off += struct.calcsize("<" + FIELD_CLASSES[f.cls])
    return None


class FoldPlan:
    """Per-model dispatch table: what (if anything) each eid contributes.

    Compiled once per :class:`~repro.core.api_model.TraceModel`.  ``rows``
    is a dense eid-indexed list of flat tuples so the fold loop does one
    list index and one tuple unpack per record — no dict lookups, no
    attribute traffic.  Keys are interned ``(provider, api)`` tuples;
    kernel-name keys are memoized per eid on the *raw* name bytes, so a
    launch span's row key costs one small-bytes hash after first sight
    (no utf-8 decode, no tuple allocation).
    """

    __slots__ = ("rows", "pair_keys", "needs_unpack")

    def __init__(self, model: TraceModel):
        self.rows: List[tuple] = [_SKIP_ROW] * len(model.events)
        #: pair_id → interned key, for the unmatched-entry flush
        self.pair_keys: List[Tuple[str, str]] = []
        #: any K_SPAN_NAMED_GENERIC eids? (engine then builds the unpackers)
        self.needs_unpack = False
        pair_of: Dict[Tuple[str, str], int] = {}
        for ev in model.events:
            key = intern_key(ev.provider, ev.api)
            if ev.eid == DISCARD_EVENT_ID and ev.phase == "meta":
                self.rows[ev.eid] = (K_DISCARD, key, -1, 0, None)
            elif ev.phase in ("entry", "exit"):
                pid = pair_of.get(key)
                if pid is None:
                    pid = pair_of[key] = len(self.pair_keys)
                    self.pair_keys.append(key)
                kind = K_ENTRY if ev.phase == "entry" else K_EXIT
                self.rows[ev.eid] = (kind, key, pid, 0, None)
            elif ev.phase == "span":
                # span payloads always open with ts_begin/ts_end (u64 each,
                # SPAN_EXTRA_FIELDS in api_model.build_trace_model)
                if (
                    len(ev.fields) >= 2
                    and ev.fields[0].name == "ts_begin"
                    and ev.fields[1].name == "ts_end"
                ):
                    noff = (
                        _fixed_offset_of(ev.fields[2:], "name")
                        if ev.api == "launch"
                        else None
                    )
                    if noff is not None:
                        # per-eid memo: raw name bytes → interned row key
                        cache: Dict[bytes, Tuple[str, str]] = {}
                        self.rows[ev.eid] = (
                            K_SPAN_NAMED,
                            key,
                            -1,
                            _SPAN_TS.size + noff,
                            cache,
                        )
                    elif ev.api == "launch" and any(
                        f.name == "name" for f in ev.fields[2:]
                    ):
                        # a name field exists but is not reachable at a fixed
                        # offset (varlen predecessor, or non-str class): fall
                        # back to a full payload unpack for this eid only, so
                        # per-kernel rows still match the legacy graph
                        idx = next(
                            i for i, f in enumerate(ev.fields) if f.name == "name"
                        )
                        self.rows[ev.eid] = (K_SPAN_NAMED_GENERIC, key, -1, idx, None)
                        self.needs_unpack = True
                    else:
                        self.rows[ev.eid] = (K_SPAN, key, -1, 0, None)
                # malformed span schema: skip (the legacy graph would fail
                # to unpack it too; lenient skip is the shared behavior)


class FoldState:
    """Mutable fold target: flat row accumulators + (pid, tid)-local stacks.

    Rows are ``[calls, total_ns, min_ns, max_ns]`` lists (cheaper to bump
    than objects); :meth:`to_tally` converts.  One state may be fed chunks
    from many streams/threads (the offline fold walks every stream file of
    a trace; the online analyzer is fed every ring's drains) — pairing
    stays correct because stacks are keyed by ``(pid, tid)`` first, API
    second, exactly like the legacy interval filter.
    """

    __slots__ = (
        "rows",
        "drows",
        "processes",
        "threads",
        "hostnames",
        "stacks",
        "events_seen",
        "discarded",
        "unmatched_exits",
    )

    def __init__(self):
        self.rows: Dict[Tuple[str, str], list] = {}  # host APIs
        self.drows: Dict[Tuple[str, str], list] = {}  # device spans
        self.processes: set = set()
        self.threads: set = set()
        self.hostnames: set = set()
        #: (pid, tid) → {pair_id → [entry timestamps]} (LIFO per API)
        self.stacks: Dict[Tuple[int, int], Dict[int, List[int]]] = {}
        self.events_seen = 0
        self.discarded = 0
        self.unmatched_exits = 0

    def to_tally(self) -> Tally:
        """Materialize the accumulated rows as a fresh Tally (the caller's
        to mutate) — stamped with the discarded total, like the offline
        legacy path."""
        t = Tally()
        t.apis = {
            k: ApiStat(calls=r[0], total_ns=r[1], min_ns=r[2], max_ns=r[3])
            for k, r in self.rows.items()
        }
        t.device_apis = {
            k: ApiStat(calls=r[0], total_ns=r[1], min_ns=r[2], max_ns=r[3])
            for k, r in self.drows.items()
        }
        t.processes |= self.processes
        t.threads |= self.threads
        t.hostnames |= self.hostnames
        t.discarded = self.discarded
        return t


class FoldEngine:
    """Executes a :class:`FoldPlan` over framed record buffers."""

    def __init__(self, model: TraceModel):
        self.model = model
        self.plan = FoldPlan(model)
        if self.plan.needs_unpack:
            # exotic span schema (name behind a varlen field): borrow the
            # generated unpackers for just those eids
            from .tracepoints import Tracepoints

            self._unpack = Tracepoints(model).unpack
        else:
            self._unpack = None

    def new_state(self) -> FoldState:
        return FoldState()

    def fold_chunk(self, state: FoldState, buf, pid: int, tid: int) -> int:
        """Fold one framed-record buffer (a ring drain or a stream region).

        Single pass, no per-record materialization: the record header is the
        batched scan unit, payloads are touched only at the two span
        timestamps (and the launch kernel name).  Returns the number of
        records consumed; a truncated tail (crash mid-write) stops cleanly,
        like ``ctf.StreamReader``.
        """
        if type(buf) is not memoryview:
            buf = memoryview(buf)  # hoisted: one wrap per chunk, not per record
        plan_rows = self.plan.rows
        nplans = len(plan_rows)
        hdr_unpack = RECORD_HEADER.unpack_from
        span_unpack = _SPAN_TS.unpack_from
        len_unpack = _LEN.unpack_from
        u64_unpack = _U64.unpack_from
        tkey = (pid, tid)
        stacks = state.stacks.get(tkey)
        if stacks is None:
            stacks = state.stacks[tkey] = {}
        rows = state.rows
        drows = state.drows
        touched = False
        events = 0
        off = 0
        n = len(buf)
        limit = n - RECORD_HEADER_SIZE
        while off <= limit:
            total, eid, ts = hdr_unpack(buf, off)
            if total < RECORD_HEADER_SIZE or off + total > n:
                break  # truncated tail — stop cleanly
            events += 1
            if eid < nplans:
                kind, key, aid, noff, nkcache = plan_rows[eid]
                if kind == K_ENTRY:
                    stack = stacks.get(aid)
                    if stack is None:
                        stacks[aid] = [ts]
                    else:
                        stack.append(ts)
                elif kind == K_EXIT:
                    stack = stacks.get(aid)
                    if stack:
                        dur = ts - stack.pop()
                        if dur < 0:
                            dur = 0
                        row = rows.get(key)
                        if row is None:
                            rows[key] = [1, dur, dur, dur]
                        else:
                            row[0] += 1
                            row[1] += dur
                            if dur < row[2]:
                                row[2] = dur
                            if dur > row[3]:
                                row[3] = dur
                        touched = True
                    else:
                        state.unmatched_exits += 1
                elif kind >= K_SPAN:
                    rec_end = off + total
                    if kind == K_DISCARD:
                        if off + RECORD_HEADER_SIZE + 8 <= rec_end:
                            state.discarded += u64_unpack(
                                buf, off + RECORD_HEADER_SIZE
                            )[0]
                        off = rec_end
                        continue
                    poff = off + RECORD_HEADER_SIZE
                    if poff + 16 > rec_end:  # short payload: never read past
                        off = rec_end  # the record into its neighbor's bytes
                        continue
                    t0, t1 = span_unpack(buf, poff)
                    dur = t1 - t0
                    if dur < 0:
                        dur = 0
                    if kind == K_SPAN_NAMED:
                        nb_off = poff + noff
                        if nb_off + 4 > rec_end:
                            off = rec_end
                            continue
                        (ln,) = len_unpack(buf, nb_off)
                        if nb_off + 4 + ln > rec_end:  # truncated name field
                            off = rec_end
                            continue
                        nb = bytes(buf[nb_off + 4 : nb_off + 4 + ln])
                        nkey = nkcache.get(nb)
                        if nkey is None:
                            # key is the plan's (provider, api): provider +
                            # decoded kernel name becomes the row key, memoized
                            nkey = nkcache[nb] = intern_key(
                                key[0], nb.decode(errors="replace")
                            )
                        key = nkey
                    elif kind == K_SPAN_NAMED_GENERIC:
                        # noff is the field index of "name" here; the legacy
                        # graph keys launch rows on entry["name"] whatever its
                        # class, so the full unpack keeps parity
                        try:
                            name = self._unpack[eid](buf[poff:rec_end])[noff]
                        except struct.error:
                            off = rec_end
                            continue
                        key = (
                            intern_key(key[0], name)
                            if type(name) is str
                            else (key[0], name)
                        )
                    row = drows.get(key)
                    if row is None:
                        drows[key] = [1, dur, dur, dur]
                    else:
                        row[0] += 1
                        row[1] += dur
                        if dur < row[2]:
                            row[2] = dur
                        if dur > row[3]:
                            row[3] = dur
                    touched = True
                # K_SKIP (samples, unknown phases): header-only cost
            else:
                # eid beyond this model: a record from a newer writer (e.g. a
                # user annotate event this reader's model predates).  Never
                # raise; when the payload opens with a plausible length-
                # prefixed name (the ust_user wire shape), surface it as a
                # name-keyed calls-only passthrough row — otherwise skip on
                # the header alone, the historical behavior.
                poff = off + RECORD_HEADER_SIZE
                rec_end = off + total
                if poff + 4 <= rec_end:
                    (ln,) = len_unpack(buf, poff)
                    if 1 <= ln <= 255 and poff + 4 + ln <= rec_end:
                        name = bytes(buf[poff + 4 : poff + 4 + ln]).decode(
                            errors="replace"
                        )
                        key = intern_key("unknown", name)
                        row = rows.get(key)
                        if row is None:
                            rows[key] = [1, 0, 0, 0]
                        else:
                            row[0] += 1
                        touched = True
            off += total
        state.events_seen += events
        if touched:
            # once per chunk, not per record — sets dedupe, result identical
            state.processes.add(pid)
            state.threads.add(tkey)
        return events

    def finish(self, state: FoldState) -> Tally:
        """Flush unmatched entries (crash mid-call / exits dropped under ring
        pressure) as zero-duration calls — the legacy interval filter's
        behavior, so validation-grade counts survive the fast path — then
        materialize the tally.  Offline-only: the live analyzer never
        flushes (an open call is simply not yet part of the live tally)."""
        rows = state.rows
        pair_keys = self.plan.pair_keys
        for (pid, tid), stacks in state.stacks.items():
            for aid, stack in stacks.items():
                if not stack:
                    continue
                key = pair_keys[aid]
                row = rows.get(key)
                if row is None:
                    rows[key] = [len(stack), 0, 0, 0]
                else:
                    row[0] += len(stack)
                    if row[2] > 0:
                        row[2] = 0
                state.processes.add(pid)
                state.threads.add((pid, tid))
            stacks.clear()
        return state.to_tally()


# ---------------------------------------------------------------------------
# Trace-level fold: sidecar fast path + sharded parallel execution
# ---------------------------------------------------------------------------


def stream_groups(paths: Sequence[str]) -> List[List[str]]:
    """Partition stream paths into ``(pid, tid)``-groups, preserving the
    sorted file order within each group.

    The grouping is the parallel-fold correctness unit: pairing stacks are
    ``(pid, tid)``-local, so streams of *different* groups share no fold
    state and may run in any order on any worker — but two files carrying
    the same ``(pid, tid)`` (multi-rank dirs with rank prefixes) must stay
    together, in order, on one worker, or an entry left open by the first
    file could no longer pair with its exit in the second.
    """
    groups: Dict[Tuple[int, int], List[str]] = {}
    for path in paths:
        reader = StreamReader(path)  # filename parse only, no I/O
        groups.setdefault((reader.pid, reader.tid), []).append(path)
    return list(groups.values())


def _fold_groups(
    trace_dir: str,
    groups: Sequence[Sequence[str]],
    use_sidecar: bool,
    meta: Optional[TraceMeta] = None,
) -> Tally:
    """Fold a set of stream groups into one tally (one worker's share).

    Per group: a trusted columnar sidecar short-circuits record parsing
    entirely (the footer carries the stream's folded tally); otherwise the
    group's records run through the shared engine.  Sidecars are per-stream
    self-contained (their unmatched entries were flushed at write time), so
    the fast path is only taken for single-stream groups — the common case;
    a multi-file ``(pid, tid)`` group needs cross-file stack continuity and
    always folds records.
    """
    if meta is None:
        meta = TraceMeta.load(trace_dir)
    engine = FoldEngine(meta.model)
    state = engine.new_state()
    from_sidecars = Tally()
    for group in groups:
        if use_sidecar and len(group) == 1:
            sc = load_sidecar(group[0])
            if sc is not None:
                from_sidecars.merge(sc.tally())
                continue
        for path in group:
            reader = StreamReader(path)
            buf, release = reader.records_region()
            try:
                engine.fold_chunk(state, buf, reader.pid, reader.tid)
            finally:
                release()
    return engine.finish(state).merge(from_sidecars)


def _fold_shard(trace_dir: str, groups: List[List[str]], use_sidecar: bool) -> dict:
    """Worker entry point: fold one shard, return a compact tally dict.

    Module-level (picklable), loads its own TraceMeta, and mmaps its streams
    via ``records_region`` — worker startup carries no parent state beyond
    the path list.  Exceptions propagate to the parent (which wraps them):
    a poisoned shard must surface, never silently truncate the tally.
    """
    return _fold_groups(trace_dir, groups, use_sidecar).to_obj()


def _partition_groups(groups: List[List[str]], shards: int) -> List[List[List[str]]]:
    """Greedy byte-balanced partition: largest group to the lightest shard."""

    def group_bytes(g: List[str]) -> int:
        return sum(os.path.getsize(p) for p in g)

    sized = sorted(((group_bytes(g), g) for g in groups), key=lambda x: -x[0])
    out: List[List[List[str]]] = [[] for _ in range(shards)]
    loads = [0] * shards
    for size, g in sized:
        i = loads.index(min(loads))
        out[i].append(g)
        loads[i] += size
    return [s for s in out if s]


def fold_trace(trace_dir: str, jobs: int = 1, use_sidecar: bool = True) -> Tally:
    """Fast-path ``tally_trace``: fold a CTF-lite trace directory directly
    into a :class:`~repro.core.plugins.tally.Tally` — no Event/Interval
    materialization, no global time-sort, one mmap'd buffer per stream.

    ``jobs > 1`` shards the per-stream work across a process pool: workers
    fold disjoint ``(pid, tid)`` stream groups through their own engine and
    return compact tally dicts the parent merges.  Because pairing state is
    ``(pid, tid)``-local, the result is identical to ``jobs=1`` for every
    job count (property-tested in ``tests/test_parallel_fold.py``).
    ``jobs=None`` means one worker per CPU.  A failing worker (corrupt
    stream, killed process) raises ``RuntimeError`` naming the cause — a
    partial tally is never returned.

    ``use_sidecar=False`` disables the columnar fast path (``.ctfcol``
    footers); the default trusts validated sidecars and skips record
    parsing for those streams.
    """
    meta = TraceMeta.load(trace_dir)
    groups = stream_groups(stream_files(trace_dir))
    if jobs is None:
        jobs = os.cpu_count() or 1
    jobs = max(1, min(int(jobs), len(groups) or 1))
    if jobs <= 1:
        tally = _fold_groups(trace_dir, groups, use_sidecar, meta=meta)
    else:
        from concurrent.futures import ProcessPoolExecutor

        shards = _partition_groups(groups, jobs)
        tally = Tally()
        try:
            with ProcessPoolExecutor(max_workers=len(shards)) as ex:
                futures = [
                    ex.submit(_fold_shard, trace_dir, shard, use_sidecar)
                    for shard in shards
                ]
                for f in futures:
                    tally.merge(Tally.from_obj(f.result()))
        except Exception as e:
            raise RuntimeError(
                f"parallel fold (jobs={jobs}) failed; no partial tally: {e}"
            ) from e
    host = meta.env.get("hostname", "")
    if host:
        tally.hostnames.add(host)
    # sampled-session estimator: a trace recorded *entirely* on the
    # "sampled" fidelity rung carries exact 1/N semantics — scale the host
    # rows into unbiased estimates.  Mixed-fidelity sessions (mid-run rung
    # flips) keep their raw conservative counts: a uniform scale would be
    # wrong for the windows recorded at other rungs, and the advisory
    # records in the trace mark exactly when the rungs changed.
    fid = meta.env.get("fidelity")
    if isinstance(fid, dict) and fid.get("modes_used") == ["sampled"]:
        interval = int(fid.get("interval", 1))
        if interval > 1:
            tally.scale(interval)
    return tally

"""Metababel: callback-dispatch generation over the trace model (THAPI §3.4).

THAPI's Metababel "attaches user-defined callbacks to trace events (generated
automatically from the LTTng trace model)", abstracting Babeltrace2's CTF
reading, field unpacking and message plumbing so plugins are just *collections
of callbacks*.

We generate, per trace model, a ``process(events)`` dispatch loop whose body
is specialized source code (one flat list indexed by event id — no dict
lookups or string compares on the hot path), exactly the role Metababel's
generated C plays.
"""

from __future__ import annotations

from typing import Callable, Iterable, List, Optional

from .api_model import TraceModel
from .babeltrace import Event

_DISPATCH_SRC = """
def process(events, _cbs=_cbs, _default=_default):
    n = 0
    for ev in events:
        cb = _cbs[ev.etype.eid]
        if cb is not None:
            cb(ev)
        elif _default is not None:
            _default(ev)
        n += 1
    return n
"""


class Dispatcher:
    """Plugin base: register callbacks by event name, run over a stream.

    >>> d = Dispatcher(model)
    >>> d.on("ust_jaxrt:memcpy_entry", lambda ev: ...)
    >>> d.run(CTFSource(trace_dir))
    """

    def __init__(self, model: TraceModel, default: Optional[Callable[[Event], None]] = None):
        self.model = model
        self._cbs: List[Optional[Callable[[Event], None]]] = [None] * len(model.events)
        self._default = default
        self._process = None  # generated lazily after registration settles

    def on(self, event_name: str, cb: Callable[[Event], None]) -> "Dispatcher":
        ev = self.model.by_name()[event_name]
        self._cbs[ev.eid] = cb
        self._process = None
        return self

    def on_provider(self, provider: str, cb: Callable[[Event], None]) -> "Dispatcher":
        for ev in self.model.events:
            if ev.provider == provider:
                self._cbs[ev.eid] = cb
        self._process = None
        return self

    def _gen(self):
        ns = {"_cbs": self._cbs, "_default": self._default}
        exec(compile(_DISPATCH_SRC, "<metababel dispatch>", "exec"), ns)
        return ns["process"]

    def run(self, events: Iterable[Event]) -> int:
        if self._process is None:
            self._process = self._gen()
        return self._process(events)

"""Compatibility layer for the jax API surface this repo uses.

The codebase targets the modern mesh/sharding API (``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=)``, ``jax.shard_map(..., check_vma=)``,
``AbstractMesh(sizes, names)``); older jaxlib builds (< 0.5) predate all four
spellings.  Every mesh/shard_map construction in the repo goes through these
helpers so the rest of the code can write the modern form once.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import AbstractMesh, Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType  # noqa: F401

    _HAS_AXIS_TYPE = True
except ImportError:
    AxisType = None
    _HAS_AXIS_TYPE = False

try:  # jax >= 0.6 exposes shard_map at top level (check_vma spelling)
    from jax import shard_map as _new_shard_map
except ImportError:
    _new_shard_map = None
    from jax.experimental.shard_map import shard_map as _old_shard_map


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), axis_types=(AxisType.Auto,) * len(axis_names)
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def device_mesh(devices, axis_names: Sequence[str]) -> Mesh:
    """``Mesh`` over an explicit device array, Auto axis types where supported."""
    if _HAS_AXIS_TYPE:
        return Mesh(devices, tuple(axis_names), axis_types=(AxisType.Auto,) * len(axis_names))
    return Mesh(devices, tuple(axis_names))


def make_abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> AbstractMesh:
    """``AbstractMesh(sizes, names)``; old jax spells it ``((name, size), ...)``."""
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map``; ``check_vma`` maps to legacy ``check_rep``."""
    if _new_shard_map is not None:
        return _new_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    return _old_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )

"""The jitted train step: loss → grads → AdamW, with microbatch gradient
accumulation, optional int8 gradient quantization, and full sharding
annotations.

``build_train_artifacts`` returns the same TracedJit the trainer executes
and the dry-run lowers — the multi-pod dry-run compiles *exactly* the
production step, not a stand-in.

Compute/comm overlap: with ``microbatches > 1`` the gradient accumulation
runs as a lax.scan whose per-microbatch DP reductions XLA schedules as async
collectives overlapping the next microbatch's backward pass (the standard
latency-hiding structure); donation of the (params, opt) state makes the
update in-place in HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.interception import TracedJit
from repro.models import Model, ShapeSpec
from repro.models.param import axes as spec_axes, shapes as spec_shapes
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.sharding import Partitioner


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()
    #: int8 quantize-dequantize gradients before the optimizer (wire-format
    #: emulation of the compressed DP reduction; see optim/compression.py)
    grad_compression: bool = False


def _tree_pspecs(partitioner: Partitioner, shapes_tree, axes_tree):
    flat_s, treedef = jax.tree_util.tree_flatten(shapes_tree)
    flat_a = jax.tree_util.tree_leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    )
    specs = [partitioner.pspec(a, s.shape) for s, a in zip(flat_s, flat_a)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_specs(model: Model, partitioner: Partitioner, tcfg: TrainConfig):
    """(state ShapeDtypeStructs, state PartitionSpecs) for {params, opt}."""
    p_shapes = model.shapes()
    p_axes = model.axes()
    p_pspecs = _tree_pspecs(partitioner, p_shapes, p_axes)
    sdt = jnp.dtype(tcfg.adamw.state_dtype)
    mom = jax.tree_util.tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, sdt), p_shapes)
    state_shapes = {
        "params": p_shapes,
        "opt": {"mu": mom, "nu": mom, "count": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    state_pspecs = {
        "params": p_pspecs,
        "opt": {"mu": p_pspecs, "nu": p_pspecs, "count": P()},
    }
    return state_shapes, state_pspecs


def batch_specs_sharded(model: Model, partitioner: Partitioner, shape: ShapeSpec):
    b_specs = model.batch_specs(shape)
    shapes = spec_shapes(b_specs, model.cfg.dtype)
    axes = spec_axes(b_specs)
    pspecs = _tree_pspecs(partitioner, shapes, axes)
    return shapes, pspecs


def _maybe_compress(grads, on: bool):
    if not on:
        return grads

    def qdq(g):
        if g.ndim == 0:
            return g
        q, s = quantize_int8(g)
        return dequantize_int8(q, s).reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map(qdq, grads)


def build_train_artifacts(
    model: Model,
    partitioner: Partitioner,
    shape: ShapeSpec,
    tcfg: TrainConfig,
):
    """Returns (TracedJit step, state_shapes, state_shardings, batch_shapes,
    batch_shardings).  step(state, batch) → (state, metrics)."""
    mesh = partitioner.mesh
    state_shapes, state_pspecs = state_specs(model, partitioner, tcfg)
    batch_shapes, batch_pspecs = batch_specs_sharded(model, partitioner, shape)

    def to_shard(tree):
        return jax.tree_util.tree_map(
            lambda ps: NamedSharding(mesh, ps), tree, is_leaf=lambda x: isinstance(x, P)
        )

    state_shardings = to_shard(state_pspecs)
    batch_shardings = to_shard(batch_pspecs)
    k = tcfg.microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step_fn(state, batch):
        params, opt = state["params"], state["opt"]
        lr = warmup_cosine(
            opt["count"], peak_lr=tcfg.peak_lr, warmup=tcfg.warmup, total=tcfg.total_steps
        )
        if k > 1:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics: Dict[str, Any] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        grads = _maybe_compress(grads, tcfg.grad_compression)
        new_params, new_opt, gnorm = adamw_update(grads, opt, params, lr, tcfg.adamw)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            "lr": lr,
            **{m: v.astype(jnp.float32) for m, v in metrics.items() if v.ndim == 0},
        }
        return {"params": new_params, "opt": new_opt}, out_metrics

    arg_bytes = sum(
        int(jnp.dtype(s.dtype).itemsize) * int(jnp.prod(jnp.asarray(s.shape)))
        for s in jax.tree_util.tree_leaves(state_shapes)
    )
    step = TracedJit(
        step_fn,
        name=f"train_step[{model.cfg.name}/{shape.name}]",
        donate_argnums=(0,),
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, None),
        flops=model.model_flops_per_token() * shape.tokens * 3,  # fwd+bwd ≈ 3×
        bytes_accessed=arg_bytes,
    )
    return step, state_shapes, state_shardings, batch_shapes, batch_shardings


def init_state(model: Model, tcfg: TrainConfig, rng, shardings=None):
    """Materialize (params, opt) — smoke/example scale only."""
    params = model.init(rng)
    opt = adamw_init(params, tcfg.adamw)
    state = {"params": params, "opt": opt}
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state

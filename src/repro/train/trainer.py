"""Fault-tolerant training loop, fully THAPI-instrumented.

This is the paper's subject *and* its substrate: every phase of the loop is
traced through the interception layer (train_step / data_next / optimizer
/ checkpoint spans, telemetry step-rate gauge), so iprof tally/timeline on a
training run reproduces the paper's §4.3 analysis on our own stack.

Fault tolerance (1000-node posture, exercised in tests):
  * checkpoint every ``ckpt_every`` steps (async commit), data state included;
  * on startup, auto-restore from the newest valid checkpoint;
  * step execution wrapped in a retry loop: a transient failure restores the
    last checkpoint and replays (``max_failures`` budget);
  * straggler watchdog: EWMA of step time; steps slower than
    ``straggler_factor``× the EWMA are counted and surfaced as warnings (on a
    real cluster this triggers rank replacement — here it feeds the trace);
  * elastic: the mesh is derived from the live device count at construction,
    and restore reshards onto it (checkpointer stores full arrays).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer, latest_checkpoint
from repro.core.interception import data_next_span, optimizer_update_span, train_step_span
from repro.core.telemetry import StepRateGauge
from repro.data import DataConfig, SyntheticPipeline
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train.train_step import TrainConfig, build_train_artifacts, init_state


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 50
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    max_failures: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    data: DataConfig = dataclasses.field(default_factory=DataConfig)


class Trainer:
    def __init__(
        self,
        model: Model,
        shape: ShapeSpec,
        partitioner: Partitioner,
        tcfg: TrainConfig,
        cfg: TrainerConfig,
        rng_seed: int = 0,
    ):
        self.model = model
        self.shape = shape
        self.partitioner = partitioner
        self.tcfg = tcfg
        self.cfg = cfg
        (
            self.step_fn,
            self.state_shapes,
            self.state_shardings,
            self.batch_shapes,
            self.batch_shardings,
        ) = build_train_artifacts(model, partitioner, shape, tcfg)
        self.state = init_state(model, tcfg, jax.random.PRNGKey(rng_seed), self.state_shardings)
        dp = partitioner.dp_size()
        self.pipe = SyntheticPipeline(model, shape, cfg.data, dp_rank=0, dp_size=dp)
        self.ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.straggler_steps = 0
        self._ewma: Optional[float] = None
        self.failures = 0

    # -- checkpoint/restore ------------------------------------------------------
    def _maybe_restore(self) -> None:
        if self.ckpt is None:
            return
        path = latest_checkpoint(self.ckpt.root)
        if path is None:
            return
        self.state, man = self.ckpt.restore(path, self.state, self.state_shardings)
        self.step = man.step
        if "data" in man.extra:
            self.pipe.load_state_dict(man.extra["data"])

    def _save(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save_async(self.step, self.state, extra={"data": self.pipe.state_dict()})

    # -- batching -----------------------------------------------------------------
    def _device_batch(self, host_batch: Dict[str, np.ndarray]):
        # dp_size=world here (single-process container): host batch is global
        return {
            k: jax.device_put(v, self.batch_shardings[k]) for k, v in host_batch.items()
        }

    # -- main loop -----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        self._maybe_restore()
        start = self.step
        while self.step < self.cfg.steps:
            try:
                self._one_step()
            except Exception:
                self.failures += 1
                if self.failures > self.cfg.max_failures or self.ckpt is None:
                    raise
                # fault tolerance: restore + replay
                self.ckpt.wait()
                self._maybe_restore()
        if self.ckpt is not None:
            self.ckpt.wait()
            self._save()
            self.ckpt.wait()
        self.pipe.stop()
        return {
            "steps_run": self.step - start,
            "final_loss": self.history[-1]["loss"] if self.history else float("nan"),
            "straggler_steps": self.straggler_steps,
            "failures": self.failures,
            "history": self.history,
        }

    def _one_step(self) -> None:
        t0 = time.monotonic()
        with data_next_span(self.step) as dsp:
            host_batch = next(self.pipe)
            batch = self._device_batch(host_batch)
            dsp.outs["tokens"] = int(np.prod(host_batch["tokens"].shape))
        with train_step_span(
            self.step, self.shape.global_batch, self.shape.seq_len
        ) as sp:
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            sp.outs["loss"] = loss
            sp.outs["grad_norm"] = gnorm
        with optimizer_update_span(self.step) as osp:
            osp.outs["lr"] = float(metrics["lr"])
        StepRateGauge.bump()
        self.step += 1
        self.history.append({"step": self.step, "loss": loss, "grad_norm": gnorm})
        if self.ckpt is not None and self.step % self.cfg.ckpt_every == 0:
            self._save()
        # straggler watchdog (EWMA of step wall time)
        dt = time.monotonic() - t0
        if self._ewma is not None and dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_steps += 1
        self._ewma = dt if self._ewma is None else 0.9 * self._ewma + 0.1 * dt

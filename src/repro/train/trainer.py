"""Fault-tolerant training loop, fully THAPI-instrumented.

This is the paper's subject *and* its substrate: every phase of the loop is
traced through the interception layer (train_step / data_next / optimizer
/ checkpoint spans, telemetry step-rate gauge), so iprof tally/timeline on a
training run reproduces the paper's §4.3 analysis on our own stack.

Fault tolerance (1000-node posture, exercised in tests):
  * checkpoint every ``ckpt_every`` steps (async commit), data state included;
  * on startup, auto-restore from the newest valid checkpoint;
  * step execution wrapped in a retry loop: a transient failure restores the
    last checkpoint and replays (``max_failures`` budget);
  * straggler watchdog (:class:`StragglerWatchdog`): EWMA of step wall time
    flags locally-slow steps, and cluster-scope adaptive control
    (``ClusterAdaptiveController`` + ``StragglerRankPolicy`` over the live
    per-rank composites) feeds **API-level evidence** — which rank, which
    API, how far behind the cluster median — into the same watchdog via
    ``trainer.straggler_callback`` (on a real cluster this triggers rank
    replacement — here it feeds the trace and the run report);
  * elastic: the mesh is derived from the live device count at construction,
    and restore reshards onto it (checkpointer stores full arrays).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer, list_checkpoints
from repro.core.interception import data_next_span, optimizer_update_span, train_step_span
from repro.core.telemetry import StepRateGauge
from repro.data import DataConfig, SyntheticPipeline
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train.train_step import TrainConfig, build_train_artifacts, init_state


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 50
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    max_failures: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    data: DataConfig = dataclasses.field(default_factory=DataConfig)


@dataclasses.dataclass
class StragglerReport:
    """API-level straggler evidence from cluster-scope adaptive control:
    which rank lagged, on which traced API, how far behind the cluster
    median, and the policy's reasoning."""

    source: str  # rank identity (host:pid:rankN)
    provider: str
    api: str
    ratio: float  # rank metric / cluster median
    reason: str = ""


class StragglerWatchdog:
    """The trainer's straggler state, fed by two evidence channels.

    * **Wall clock** (local): :meth:`observe_step` keeps an EWMA of step
      time; a step slower than ``factor`` × EWMA counts as a slow step.
      This knows *that* this rank had a slow step — never *why*, and never
      whether the slowness is this rank's fault or a collective stalled on
      someone else.
    * **API level** (cluster): :meth:`note_api_evidence` matches the
      ``on_straggler`` callback signature of ``ClusterAdaptiveController``
      — cluster-scope policies watching the live per-rank composites report
      the lagging rank, the API it lags on, and the skew ratio.  Reports
      accumulate in :attr:`reports` (thread-safe: the cluster controller
      ticks on the tracer's consumer thread while the step loop runs).

    On a real cluster the combination drives rank replacement; here it
    feeds the trace and the run report, which is exactly the paper's
    "comprehensive tracing lets you *act* on performance problems" loop.
    """

    def __init__(self, factor: float = 3.0, decay: float = 0.9):
        self.factor = factor
        self.decay = decay
        self.slow_steps = 0
        self.reports: List[StragglerReport] = []
        self._ewma: Optional[float] = None
        self._lock = threading.Lock()

    @property
    def ewma_s(self) -> Optional[float]:
        """Current step-time EWMA in seconds (None before the first step)."""
        return self._ewma

    def observe_step(self, dt_s: float) -> bool:
        """Feed one step's wall time; True when it counted as a slow step."""
        slow = self._ewma is not None and dt_s > self.factor * self._ewma
        if slow:
            self.slow_steps += 1
        self._ewma = (
            dt_s
            if self._ewma is None
            else self.decay * self._ewma + (1.0 - self.decay) * dt_s
        )
        return slow

    def note_api_evidence(
        self, source: str, provider: str, api: str, ratio: float, reason: str = ""
    ) -> None:
        """Ingest one cluster-scope straggler report (``on_straggler`` hook)."""
        with self._lock:
            self.reports.append(
                StragglerReport(source, provider, api, float(ratio), reason)
            )

    def api_reports(self) -> List[StragglerReport]:
        """Snapshot of the API-level evidence received so far."""
        with self._lock:
            return list(self.reports)


class Trainer:
    def __init__(
        self,
        model: Model,
        shape: ShapeSpec,
        partitioner: Partitioner,
        tcfg: TrainConfig,
        cfg: TrainerConfig,
        rng_seed: int = 0,
    ):
        self.model = model
        self.shape = shape
        self.partitioner = partitioner
        self.tcfg = tcfg
        self.cfg = cfg
        (
            self.step_fn,
            self.state_shapes,
            self.state_shardings,
            self.batch_shapes,
            self.batch_shardings,
        ) = build_train_artifacts(model, partitioner, shape, tcfg)
        self.state = init_state(model, tcfg, jax.random.PRNGKey(rng_seed), self.state_shardings)
        dp = partitioner.dp_size()
        self.pipe = SyntheticPipeline(model, shape, cfg.data, dp_rank=0, dp_size=dp)
        self.ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.watchdog = StragglerWatchdog(factor=cfg.straggler_factor)
        self.failures = 0
        # -- drain machinery (remediation rung 2) --
        #: set (from any thread) to ask the loop to checkpoint-and-drain at
        #: the next step boundary instead of running to cfg.steps
        self.draining = threading.Event()
        #: True once a drain checkpoint has been durably committed — the
        #: remediation ladder requires this before evicting the rank
        self.drained = False
        #: quiesce hooks: called (in order, exceptions contained) after the
        #: drain checkpoint commits — stop data pipelines, close streams,
        #: release device handles before the host is taken away
        self.on_drain: List[Callable[[], None]] = []
        #: which incarnation of this logical rank the loop is running as —
        #: bumped by :meth:`admit_replacement`; the streaming layer fences
        #: frames from lower incarnations (zombie containment)
        self.incarnation = 0

    @property
    def straggler_steps(self) -> int:
        """Wall-clock-slow steps counted by the watchdog's EWMA channel."""
        return self.watchdog.slow_steps

    @property
    def straggler_callback(self) -> Callable[[str, str, str, float, str], None]:
        """The ``on_straggler`` hook for a ``ClusterAdaptiveController``:
        API-level straggler evidence lands in this trainer's watchdog."""
        return self.watchdog.note_api_evidence

    # -- checkpoint/restore ------------------------------------------------------
    def _maybe_restore(self) -> None:
        if self.ckpt is None:
            return
        # walk newest → oldest: a damaged restore point (truncated leaf,
        # corrupt manifest, failed CRC) falls back to the next-older one
        # instead of killing the run
        for path in list_checkpoints(self.ckpt.root):
            try:
                self.state, man = self.ckpt.restore(path, self.state, self.state_shardings)
            except Exception:
                continue
            self.step = man.step
            if "data" in man.extra:
                self.pipe.load_state_dict(man.extra["data"])
            return

    def _save(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save_async(self.step, self.state, extra={"data": self.pipe.state_dict()})

    # -- checkpoint-and-drain (remediation rung 2) --------------------------------
    def request_drain(self) -> None:
        """Ask the running loop to drain at the next step boundary.

        Thread-safe: this is what a :class:`~repro.core.remediation.
        RemediationEngine` drain hook calls from the tracer's consumer
        thread while the step loop runs.
        """
        self.draining.set()

    def checkpoint_and_drain(self) -> Optional[str]:
        """Quiesce the trainer: commit a durable checkpoint of the current
        state, run the quiesce hooks, and mark the rank drained.

        Returns the committed checkpoint path (None without a checkpointer —
        the rank still quiesces, it just has nothing durable to hand over).
        Idempotent: a second call re-commits but hooks run once per drain.
        The remediation ladder's *drain-before-evict* invariant is anchored
        on :attr:`drained` turning True here and nowhere else.
        """
        self.draining.set()
        path = None
        if self.ckpt is not None:
            self.ckpt.wait()  # join any in-flight async commit first
            path = self.ckpt.save(
                self.step, self.state, extra={"data": self.pipe.state_dict()}
            )
        already = self.drained
        self.drained = True
        if not already:
            for hook in list(self.on_drain):
                try:
                    hook()
                except Exception:
                    pass  # quiesce hooks must not block the drain
        return path

    # -- elastic rejoin (remediation rung: replace) -------------------------------
    def admit_replacement(self, incarnation: int, extra_steps: int = 0) -> int:
        """Rejoin barrier for a replacement incarnation of this rank.

        Called in the replacement process before :meth:`run`: restores from
        the newest undamaged checkpoint (normally the predecessor's drain
        checkpoint), clears the drain latch the predecessor tripped, records
        the new ``incarnation`` (its fencing credential on the stream), and
        extends the step budget by ``extra_steps`` — the work the mesh
        splice clawed back from the survivors.  Returns the restored step.
        """
        inc = int(incarnation)
        if inc < 0:
            raise ValueError("incarnation must be >= 0")
        if self.ckpt is not None:
            self.ckpt.wait()  # never race an in-flight async commit
        self._maybe_restore()
        self.draining.clear()
        self.drained = False
        self.incarnation = inc
        if extra_steps:
            self.cfg.steps += int(extra_steps)
        return self.step

    # -- batching -----------------------------------------------------------------
    def _device_batch(self, host_batch: Dict[str, np.ndarray]):
        # dp_size=world here (single-process container): host batch is global
        return {
            k: jax.device_put(v, self.batch_shardings[k]) for k, v in host_batch.items()
        }

    # -- main loop -----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        self._maybe_restore()
        start = self.step
        while self.step < self.cfg.steps and not self.draining.is_set():
            try:
                self._one_step()
            except Exception:
                self.failures += 1
                if self.failures > self.cfg.max_failures or self.ckpt is None:
                    raise
                # fault tolerance: restore + replay
                try:
                    self.ckpt.wait()
                except Exception:
                    self.failures += 1  # a failed async commit also burns budget
                    if self.failures > self.cfg.max_failures:
                        raise
                self._maybe_restore()
        if self.draining.is_set():
            # drain requested mid-run: durable checkpoint + quiesce hooks,
            # then hand back early with drained=True
            self.checkpoint_and_drain()
        elif self.ckpt is not None:
            self.ckpt.wait()
            self._save()
            self.ckpt.wait()
        self.pipe.stop()
        return {
            "steps_run": self.step - start,
            "final_loss": self.history[-1]["loss"] if self.history else float("nan"),
            "straggler_steps": self.straggler_steps,
            "straggler_reports": self.watchdog.api_reports(),
            "failures": self.failures,
            "drained": self.drained,
            "history": self.history,
        }

    def _one_step(self) -> None:
        t0 = time.monotonic()
        with data_next_span(self.step) as dsp:
            host_batch = next(self.pipe)
            batch = self._device_batch(host_batch)
            dsp.outs["tokens"] = int(np.prod(host_batch["tokens"].shape))
        with train_step_span(
            self.step, self.shape.global_batch, self.shape.seq_len
        ) as sp:
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            sp.outs["loss"] = loss
            sp.outs["grad_norm"] = gnorm
        with optimizer_update_span(self.step) as osp:
            osp.outs["lr"] = float(metrics["lr"])
        StepRateGauge.bump()
        self.step += 1
        self.history.append({"step": self.step, "loss": loss, "grad_norm": gnorm})
        if self.ckpt is not None and self.step % self.cfg.ckpt_every == 0:
            self._save()
        # straggler watchdog (EWMA of step wall time; API-level evidence
        # arrives asynchronously via straggler_callback)
        self.watchdog.observe_step(time.monotonic() - t0)

from .train_step import TrainConfig, build_train_artifacts  # noqa: F401
from .trainer import (  # noqa: F401
    StragglerReport,
    StragglerWatchdog,
    Trainer,
    TrainerConfig,
)

from .train_step import TrainConfig, build_train_artifacts  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401

"""Gradient compression for the data-parallel reduction.

Two wire formats, both usable inside the shard_map gradient reduction so the
*compressed* representation is what crosses the ICI/DCN links (visible as s8
all-gathers in the dry-run HLO — the roofline's collective term shrinks ~2×
for bf16→int8):

  * ``quantize_int8`` — per-block absmax int8 quantization (block = last-dim
    rows), error-feedback-free (unbiased enough for DP-mean);
  * ``topk_sparsify`` — magnitude top-k with index+value payloads, for the
    sparser inter-pod (DCN) hop.

``compressed_mean`` is the drop-in replacement for ``lax.pmean`` over the
data axes: quantize locally → all_gather(int8 + scales) → dequantize → mean.
all_gather moves ~half the bytes of the bf16 psum and the accumulate happens
in f32 locally (no int overflow).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [...,] → (int8 values, f32 per-row scales). Rows = leading dims."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(-1, xf.shape[-1]) if xf.ndim > 1 else xf.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    if x.ndim > 1:
        return q.reshape(x.shape), scale.reshape(x.shape[:-1] + (1,))
    return q.reshape(-1), scale.reshape((1,))


def dequantize_int8(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the k largest-magnitude entries (flattened); returns (values, idx)."""
    flat = x.astype(jnp.float32).reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(vals, idx, size: int) -> jnp.ndarray:
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals)


def compressed_mean(x, axis_name) -> jnp.ndarray:
    """int8-compressed mean over a mesh axis (shard_map context only).

    Wire bytes: ~1 byte/elem (+ scales) vs 2 (bf16) / 4 (f32) for pmean.
    """
    q, scale = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name)  # s8 on the wire
    sg = jax.lax.all_gather(scale, axis_name)
    deq = qg.astype(jnp.float32) * sg
    return jnp.mean(deq, axis=0).astype(x.dtype)

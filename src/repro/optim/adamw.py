"""AdamW with decoupled weight decay, global-norm clipping and configurable
optimizer-state dtype.

``state_dtype="bfloat16"`` halves optimizer memory — the knob that lets the
1T kimi config approach the 16 GB/chip budget (EXPERIMENTS §Dry-run); f32 is
the default elsewhere.  Optimizer state shards exactly like its parameter
(same logical axes), so ZeRO-style sharding falls out of the partitioner.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree)
        )
    )


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, state, params, lr, cfg: AdamWConfig
) -> Tuple[dict, dict, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, gnorm

from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm  # noqa: F401
from .compression import compressed_mean, dequantize_int8, quantize_int8, topk_sparsify  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401

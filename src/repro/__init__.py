"""repro — THAPI (Tracing Heterogeneous APIs) reproduced as a JAX/TPU training
and serving framework.

Layout:
  repro.core      — the paper's contribution: API-model-driven tracing (C1–C7)
  repro.models    — 10-architecture model zoo (dense/MoE/SSM/hybrid/enc-dec/VLM)
  repro.kernels   — Pallas TPU kernels for substrate hot spots (+ jnp oracles)
  repro.data      — deterministic sharded data pipeline
  repro.optim     — AdamW, schedules, gradient compression
  repro.checkpoint— async atomic sharded checkpoints, elastic restore
  repro.train     — train_step + fault-tolerant trainer
  repro.serve     — KV-cache serving engine (prefill/decode)
  repro.sharding  — logical-axis partitioning rules
  repro.configs   — one module per assigned architecture
  repro.launch    — production mesh, multi-pod dry-run, roofline
"""

__version__ = "1.0.0"

"""Dense decoder-only LM (llama/qwen/mistral/stablelm/h2o-danube families)
plus the VLM variant (llava-next: stub patch embeddings prepended).

Layers are stacked and scanned (jax.lax.scan) so the HLO stays O(1) in depth
— essential for compiling 88-layer configs in the dry-run.  Remat policy is
applied to the scanned block body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    attend,
    attend_cfg,
    attn_out,
    attn_specs,
    cache_update,
    embed,
    embed_specs,
    kv_cache_specs,
    mlp_specs,
    norm_spec,
    qkv,
    unembed,
)


def model_scan(cfg: ModelConfig, body, init, xs):
    """lax.scan over layer stacks; unrolled for roofline extrapolation."""
    return jax.lax.scan(body, init, xs, unroll=cfg.num_layers if cfg.scan_unroll else 1)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def specs(cfg: ModelConfig) -> dict:
    L = cfg.num_layers
    return {
        "embed": embed_specs(cfg),
        "blocks": {
            "attn": attn_specs(cfg, stacked=L),
            "mlp": mlp_specs(cfg, stacked=L),
            "ln1": norm_spec(cfg, stacked=L),
            "ln2": norm_spec(cfg, stacked=L),
        },
        "ln_f": norm_spec(cfg),
    }


def block(cfg: ModelConfig, p: dict, x, positions):
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = qkv(cfg, p["attn"], h, positions)
    ctx = attend_cfg(cfg, q, k, v, causal=True, window=cfg.sliding_window)
    x = x + attn_out(p["attn"], ctx)
    h = apply_norm(cfg, p["ln2"], x)
    return x + apply_mlp(cfg, p["mlp"], h)


def hidden_states(cfg: ModelConfig, params: dict, x, positions):
    body = _remat(cfg, lambda h, pl: (block(cfg, pl, h, positions), None))
    x, _ = model_scan(cfg, body, x, params["blocks"])
    return apply_norm(cfg, params["ln_f"], x)


def forward_train(cfg: ModelConfig, params: dict, batch: dict):
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    if cfg.vision_tokens:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x = hidden_states(cfg, params, x, positions)
    return unembed(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode against a KV cache
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return kv_cache_specs(cfg, batch, cache_len, cfg.num_layers)


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    """Run the prompt, return last-position logits + a filled KV cache."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    if cfg.vision_tokens:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    eff = cache_len if cfg.sliding_window is None else min(cache_len, cfg.sliding_window)
    positions = jnp.arange(S)[None, :]

    def body(h, pl):
        hn = apply_norm(cfg, pl["ln1"], h)
        q, k, v = qkv(cfg, pl["attn"], hn, positions)
        ctx = attend_cfg(cfg, q, k, v, causal=True, window=cfg.sliding_window)
        h = h + attn_out(pl["attn"], ctx)
        hn = apply_norm(cfg, pl["ln2"], h)
        h = h + apply_mlp(cfg, pl["mlp"], hn)
        # keep the last `eff` positions (post-RoPE K, ready for ring decode)
        if S >= eff:
            k_keep, v_keep = k[:, -eff:], v[:, -eff:]
            if cfg.sliding_window is not None and S > eff:
                # ring layout: slot of position p is p % eff
                k_keep = jnp.roll(k_keep, S % eff, axis=1)
                v_keep = jnp.roll(v_keep, S % eff, axis=1)
        else:  # room to grow: fill slots [0, S), zero the tail
            pad = [(0, 0), (0, eff - S), (0, 0), (0, 0)]
            k_keep, v_keep = jnp.pad(k, pad), jnp.pad(v, pad)
        return h, (k_keep, v_keep)

    x, (ks, vs) = model_scan(cfg, _remat(cfg, body), x, params["blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])
    cache = {"k": ks, "v": vs, "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    """One new token against the cache; returns (logits, new cache)."""
    token = batch["token"]  # [B]
    lengths = cache["len"]  # absolute #tokens generated so far
    x = embed(params["embed"], token[:, None])  # [B, 1, d]
    positions = lengths[:, None]

    def body(h, inputs):
        pl, ck, cv = inputs
        hn = apply_norm(cfg, pl["ln1"], h)
        q, k, v = qkv(cfg, pl["attn"], hn, positions)
        ck, cv = cache_update(ck, cv, k, v, lengths, cfg.sliding_window)
        kv_valid = jnp.minimum(lengths + 1, ck.shape[1])
        ctx = attend(q, ck, cv, causal=False, q_offset=None, kv_len=kv_valid)
        h = h + attn_out(pl["attn"], ctx)
        hn = apply_norm(cfg, pl["ln2"], h)
        h = h + apply_mlp(cfg, pl["mlp"], hn)
        return h, (ck, cv)

    x, (ks, vs) = model_scan(cfg, body, x, (params["blocks"], cache["k"], cache["v"]))
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, {"k": ks, "v": vs, "len": lengths + 1}

from .config import (  # noqa: F401
    SHAPES,
    EncDecConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    SSMConfig,
    ShapeSpec,
    applicable_shapes,
)
from .model import Model  # noqa: F401

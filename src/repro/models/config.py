"""Unified model configuration covering all 10 assigned architectures.

One dataclass, family-specific sub-configs.  Every ``src/repro/configs/<id>.py``
builds one of these with the exact published numbers; smoke tests build
``cfg.smoke()`` reductions of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

DTYPES = ("float32", "bfloat16")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    #: router jitter/aux-loss weight (load balancing, standard switch loss)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    #: groups for B/C projections (Mamba2 'ngroups')
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    #: recurrent width (RecurrentGemma lru_width; defaults to d_model)
    width: int = 0
    d_conv: int = 4
    #: block pattern, repeated: RecurrentGemma is (rec, rec, attn)
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 24
    #: encoder context (whisper: 1500 mel frames after the conv frontend STUB)
    enc_positions: int = 1500
    #: decoder learned-position table, sized to the largest assigned decode
    #: shape (whisper's real 448 is exceeded by decode_32k — DESIGN.md §4)
    dec_positions: int = 32_768


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA (h2o-danube)
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tied_embeddings: bool = False
    #: vocab padded to this multiple for clean TP over the model axis
    vocab_multiple: int = 128
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    #: VLM: number of stub patch-embedding tokens prepended to the prompt
    vision_tokens: int = 0
    dtype: str = "bfloat16"
    #: fsdp=True shards weight 'embed' dims over data axes too (ZeRO-3);
    #: required to fit the 1T kimi config (DESIGN.md §4)
    fsdp: bool = False
    remat: str = "none"  # none | dots | full
    #: does the arch support O(1)-state / windowed decode at 500k?
    subquadratic: bool = False
    #: unroll layer scans (roofline depth-extrapolation compiles only —
    #: XLA cost_analysis counts a while-loop body once, so the dry-run
    #: compiles unrolled k/2k-depth variants and extrapolates linearly)
    scan_unroll: bool = False

    # -- §Perf hillclimb knobs (beyond-paper optimizations) -------------------
    #: pad attention head counts up to this multiple so they shard over the
    #: 16-way model axis (qwen 40→48, llava 56→64). 0 = off (baseline).
    head_pad_to: int = 0
    #: attention impl for train/prefill: "dense" materializes [S,T] scores;
    #: "chunked" scans KV blocks with an online softmax (flash-style)
    attn_impl: str = "dense"
    attn_chunk: int = 2048
    #: MoE serving: 2D expert sharding (experts over model × FFN over data)
    #: with activation-gather decode — weights stay resident instead of the
    #: FSDP per-step weight all-gather
    serve_2d: bool = False

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def _pad_heads(self, h: int) -> int:
        if self.head_pad_to <= 1 or h % self.head_pad_to == 0:
            return h
        return ((h + self.head_pad_to - 1) // self.head_pad_to) * self.head_pad_to

    @property
    def padded_heads(self) -> int:
        return self._pad_heads(self.num_heads)

    @property
    def padded_kv_heads(self) -> int:
        # keep GQA grouping integral: pad kv only if q-per-kv stays integer
        kvp = self._pad_heads(self.num_kv_heads)
        return kvp if self.padded_heads % kvp == 0 else self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim_
        H, Kv, L, V = self.num_heads, self.num_kv_heads, self.num_layers, self.padded_vocab
        emb = V * d * (1 if self.tied_embeddings else 2)
        attn = d * (H * hd) + 2 * d * (Kv * hd) + (H * hd) * d
        if self.qkv_bias:
            attn += (H + 2 * Kv) * hd
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        norms = 2 * d
        per_layer = attn + mlp + norms
        total = emb
        if self.family == "moe":
            assert self.moe is not None
            moe_mlp = self.moe.num_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.num_experts
            total += L * (attn + moe_mlp + norms)
        elif self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            g = self.ssm.n_groups
            in_proj = d * (2 * di + 2 * g * self.ssm.d_state + nh)
            total += L * (in_proj + di * d + self.ssm.d_conv * (di + 2 * g * self.ssm.d_state) + 2 * nh + d)
        elif self.family == "hybrid":
            assert self.rglru is not None
            w = self.rglru.width or d
            nb = max(self.num_heads, 1)
            rec = (
                d * 2 * w  # gate + x projections
                + w * d  # out projection
                + self.rglru.d_conv * w  # temporal conv
                + 2 * (w * (w // nb) + w)  # block-diagonal r/i gates + biases
                + w  # Λ
            )
            n_attn, n_rec = self.block_counts()
            total += n_rec * (rec + mlp + norms) + n_attn * per_layer
        elif self.family == "audio":
            assert self.encdec is not None
            cross = d * (H * hd) + 2 * d * (Kv * hd) + (H * hd) * d
            total += self.encdec.enc_layers * per_layer + L * (per_layer + cross + d)
            # learned positional tables (encoder frames + decoder positions)
            total += (self.encdec.enc_positions + self.encdec.dec_positions) * d
        else:  # dense / vlm
            total += L * per_layer
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.num_params()
        assert self.moe is not None
        d, L = self.d_model, self.num_layers
        dense_total = self.num_params()
        all_expert = L * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        act_expert = L * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return dense_total - all_expert + act_expert

    def block_counts(self) -> Tuple[int, int]:
        """(attention blocks, recurrent blocks) for hybrid configs."""
        if self.family != "hybrid":
            return (self.num_layers, 0)
        assert self.rglru is not None
        pat = self.rglru.pattern
        groups, rem = divmod(self.num_layers, len(pat))
        n_attn = groups * sum(1 for b in pat if b == "attn") + sum(
            1 for b in pat[:rem] if b == "attn"
        )
        return (n_attn, self.num_layers - n_attn)

    # -- smoke reduction -------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.family != "hybrid" else 6),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            vocab_multiple=16,
            dtype="float32",
            fsdp=False,
            remat="none",
        )
        if self.moe:
            kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
        if self.ssm:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8)
        if self.rglru:
            kw["rglru"] = RGLRUConfig(width=64, pattern=self.rglru.pattern, local_window=16)
        if self.encdec:
            kw["encdec"] = EncDecConfig(enc_layers=2, enc_positions=16, dec_positions=64)
        if self.vision_tokens:
            kw["vision_tokens"] = 8
        if self.sliding_window:
            kw["sliding_window"] = 16
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Assigned input shapes (one set for all LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def with_depth(cfg: ModelConfig, units: int) -> ModelConfig:
    """Depth-scaled copy (same width/sharding) with unrolled scans, for the
    dry-run's cost extrapolation. ``units`` are depth units (see depth_units)."""
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, num_layers=units * len(cfg.rglru.pattern), scan_unroll=True
        )
    if cfg.family == "audio":
        return dataclasses.replace(
            cfg,
            num_layers=units,
            encdec=dataclasses.replace(cfg.encdec, enc_layers=units),
            scan_unroll=True,
        )
    return dataclasses.replace(cfg, num_layers=units, scan_unroll=True)


def depth_units(cfg: ModelConfig) -> float:
    """Model depth in extrapolation units (hybrid: pattern groups — the 26-
    layer RecurrentGemma is 8.67 groups, tail approximated as fractional)."""
    if cfg.family == "hybrid":
        return cfg.num_layers / len(cfg.rglru.pattern)
    return float(cfg.num_layers)


def applicable_shapes(cfg: ModelConfig):
    """Which assigned shapes run for this arch (DESIGN.md §4 skip table)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # full-attention archs skip 500k decode
        out.append(s)
    return out

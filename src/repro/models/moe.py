"""Mixture-of-Experts LM (moonshot 64e/top-6, kimi-k2 384e/top-8).

Expert parallelism is explicit (shard_map + lax.all_to_all over the "model"
axis) rather than GSPMD-inferred, so the collective schedule is transparent
— the dispatch/combine all_to_alls are exactly the bytes the roofline's
collective term counts, and the §Perf hillclimb can attack them directly
(capacity factor, int8 dispatch compression).

Two dispatch paths:
  * ``_moe_ep_seq``     — train/prefill: tokens sequence-sharded over the
    model axis; sort-based grouping; a2a to expert shards; grouped GEMMs;
    a2a back; weighted combine. DeepSeek-EP style, adapted to TPU/JAX.
  * ``_moe_ep_replicated`` — decode (seq=1): tokens replicated over the
    model axis; each shard computes only its local experts' contribution;
    psum combine. No a2a on the latency-critical decode path.
Fallback ``_moe_dense`` (all experts, masked combine) is the oracle for
tests and the single-device smoke path.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.jaxcompat import shard_map as _shard_map

from .config import ModelConfig
from .layers import (
    apply_norm,
    attend,
    attend_cfg,
    attn_out,
    attn_specs,
    cache_update,
    embed,
    embed_specs,
    kv_cache_specs,
    norm_spec,
    qkv,
    unembed,
)
from .param import Spec
from .transformer import _remat, model_scan


def specs(cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    L, d, E, ffe = cfg.num_layers, cfg.d_model, cfg.moe.num_experts, cfg.moe.d_ff_expert
    return {
        "embed": embed_specs(cfg),
        "blocks": {
            "attn": attn_specs(cfg, stacked=L),
            "router": Spec((L, d, E), ("layers", "embed", None)),  # replicated: global top-k
            "w_gate": Spec((L, E, d, ffe), ("layers", "experts", "embed", "expert_mlp")),
            "w_up": Spec((L, E, d, ffe), ("layers", "experts", "embed", "expert_mlp")),
            "w_down": Spec((L, E, ffe, d), ("layers", "experts", "expert_mlp", "embed")),
            "ln1": norm_spec(cfg, stacked=L),
            "ln2": norm_spec(cfg, stacked=L),
        },
        "ln_f": norm_spec(cfg),
    }


# ---------------------------------------------------------------------------
# Sort-based token grouping (static shapes; overflow drops, standard capacity)
# ---------------------------------------------------------------------------


def group_tokens(xt, eid, tok, n_groups: int, capacity: int):
    """Group assignment rows into a [n_groups, capacity, d] buffer.

    eid may contain the sentinel ``n_groups`` for invalid assignments (they
    sort last and scatter out-of-bounds → dropped).  Returns (buffer,
    eid_sorted, pos, order) — the metadata needed to ungroup results.
    """
    A = eid.shape[0]
    order = jnp.argsort(eid)  # stable
    eid_s = eid[order]
    tok_s = tok[order]
    seg_start = jnp.searchsorted(eid_s, jnp.arange(n_groups))
    pos = jnp.arange(A) - seg_start[jnp.clip(eid_s, 0, n_groups - 1)]
    buf = jnp.zeros((n_groups, capacity, xt.shape[-1]), xt.dtype)
    buf = buf.at[eid_s, pos].add(xt[tok_s])  # OOB (sentinel / pos>=cap) dropped
    return buf, eid_s, pos, order, tok_s


def ungroup_tokens(y, eid_s, pos, n_tokens: int, tok_s, weights_s):
    """Inverse of group_tokens + weighted combine into [n_tokens, d]."""
    ya = y.at[eid_s, pos].get(mode="fill", fill_value=0)  # [A, d]
    out = jnp.zeros((n_tokens, y.shape[-1]), y.dtype)
    return out.at[tok_s].add(ya * weights_s[:, None])


def expert_ffn(buf, w_gate, w_up, w_down):
    """Grouped GEMMs: [E, C, d] × [E, d, f] — the MXU-friendly MoE core."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _router(cfg: ModelConfig, wr, xt):
    """Returns (weights [T,k], expert ids [T,k], aux load-balance loss)."""
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)  # renormalize top-k
    # switch-style aux loss: E * Σ_e (fraction dispatched) * (mean prob)
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    return vals.astype(xt.dtype), idx, aux


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Dispatch paths
# ---------------------------------------------------------------------------


def _moe_dense(cfg: ModelConfig, p: dict, xt):
    """Oracle: every expert on every token, masked combine. O(T·E·d·f)."""
    E = cfg.moe.num_experts
    w, idx, aux = _router(cfg, p["router"], xt)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) * jnp.einsum(
        "td,edf->tef", xt, p["w_up"]
    )
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])  # [T, E, d]
    combine = (
        jnp.zeros((xt.shape[0], E), xt.dtype)
        .at[jnp.arange(xt.shape[0])[:, None], idx]
        .add(w)
    )
    return jnp.einsum("ted,te->td", y_all, combine), aux


def _local_expert_compute(cfg, p_local, xt, w, idx, ep: int, my_shard, capacity: int):
    """Group tokens routed to *this shard's* experts, run them, combine."""
    E = cfg.moe.num_experts
    E_loc = E // ep
    T, k = idx.shape
    a_eid = idx.reshape(-1)  # global expert ids, [T*k]
    a_tok = jnp.repeat(jnp.arange(T), k)
    a_w = w.reshape(-1)
    mine = (a_eid // E_loc) == my_shard
    loc_eid = jnp.where(mine, a_eid % E_loc, E_loc)  # sentinel E_loc
    buf, eid_s, pos, order, tok_s = group_tokens(xt, loc_eid, a_tok, E_loc, capacity)
    y = expert_ffn(buf, p_local["w_gate"], p_local["w_up"], p_local["w_down"])
    w_s = jnp.where(mine, a_w, 0.0)[order]
    return ungroup_tokens(y, eid_s, pos, T, tok_s, w_s)


def _moe_ep_replicated(cfg: ModelConfig, p: dict, x, mesh: Mesh, dp_axes):
    """Decode path: x replicated over 'model'; local experts + psum combine."""
    B, S, d = x.shape
    ep = mesh.shape["model"]
    cf = cfg.moe.capacity_factor

    def inner(pr, pg, pu, pd, xl):
        Bl = xl.shape[0]
        T = Bl * S
        xt = xl.reshape(T, d)
        wr, idx, aux = _router(cfg, pr, xt)
        my = jax.lax.axis_index("model")
        cap = max(int(np.ceil(T * cfg.moe.top_k * cf / ep)), 4)
        out = _local_expert_compute(
            cfg, {"w_gate": pg, "w_up": pu, "w_down": pd}, xt, wr, idx, ep, my, cap
        )
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))  # replicate for out_spec P()
        return out.reshape(Bl, S, d), aux

    fn = _shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P(dp_axes)),
        out_specs=(P(dp_axes), P()),
        check_vma=False,
    )
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def _moe_ep_2d(cfg: ModelConfig, p: dict, x, mesh: Mesh, dp_axes):
    """Resident 2D expert sharding for decode (§Perf, kimi-k2 hillclimb).

    Weights: experts over "model" × expert-FFN dim over the data axes —
    nothing is re-gathered per step.  Tokens are all_gather'ed over the data
    axes (MBs), each (data, model) shard computes its expert slice's partial
    FFN (column/row parallel over expert_mlp), and a single psum over the
    whole mesh combines expert contributions (model) and FFN partials (data)
    at once.  Collective bytes per layer scale with activations, not weights.
    """
    B, S, d = x.shape
    ep = mesh.shape["model"]
    E_loc = cfg.moe.num_experts // ep
    cf = cfg.moe.capacity_factor

    def inner(pr, pg, pu, pd, xl):
        # gather the (tiny) decode activations over the data axes
        xg = xl
        for a in dp_axes:
            xg = jax.lax.all_gather(xg, a, axis=0, tiled=True)
        T = xg.shape[0] * xg.shape[1]
        xt = xg.reshape(T, d)
        wr, idx, aux = _router(cfg, pr, xt)
        my = jax.lax.axis_index("model")
        cap = max(int(np.ceil(T * cfg.moe.top_k * cf / ep)), 4)
        # grouping identical to the replicated path, but the FFN runs on
        # expert_mlp-sharded weights -> results are partial over "data"
        a_eid = idx.reshape(-1)
        a_tok = jnp.repeat(jnp.arange(T), cfg.moe.top_k)
        a_w = wr.reshape(-1)
        mine = (a_eid // E_loc) == my
        loc_eid = jnp.where(mine, a_eid % E_loc, E_loc)
        buf, eid_s, pos, order, tok_s = group_tokens(xt, loc_eid, a_tok, E_loc, cap)
        y = expert_ffn(buf, pg, pu, pd)
        w_s = jnp.where(mine, a_w, 0.0)[order]
        out_all = ungroup_tokens(y, eid_s, pos, T, tok_s, w_s)
        out_all = jax.lax.psum(out_all, ("model",) + tuple(dp_axes))
        # slice this shard's batch rows back out
        rows = xl.shape[0] * S
        flat_idx = jnp.zeros((), jnp.int32)
        for a in dp_axes:
            flat_idx = flat_idx * mesh.shape[a] + jax.lax.axis_index(a)
        out = jax.lax.dynamic_slice_in_dim(out_all, flat_idx * rows, rows, axis=0)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out.reshape(xl.shape[0], S, d), aux

    fn = _shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(),
            P("model", None, dp_axes),
            P("model", None, dp_axes),
            P("model", dp_axes, None),
            P(dp_axes),
        ),
        out_specs=(P(dp_axes), P()),
        check_vma=False,
    )
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def _moe_ep_seq(cfg: ModelConfig, p: dict, x, mesh: Mesh, dp_axes):
    """Train/prefill path: sequence-sharded dispatch with all_to_all."""
    B, S, d = x.shape
    ep = mesh.shape["model"]
    E = cfg.moe.num_experts
    E_loc = E // ep
    k = cfg.moe.top_k
    cf = cfg.moe.capacity_factor

    def inner(pr, pg, pu, pd, xl):
        Bl, Sl = xl.shape[0], xl.shape[1]
        T = Bl * Sl  # tokens on this shard
        xt = xl.reshape(T, d)
        wr, idx, aux = _router(cfg, pr, xt)
        # --- send-side grouping by destination shard --------------------
        a_eid = idx.reshape(-1)
        a_tok = jnp.repeat(jnp.arange(T), k)
        a_w = wr.reshape(-1)
        dst = a_eid // E_loc  # [T*k] destination shard
        cap_send = _round_up(max(int(np.ceil(T * k * cf / ep)), 4), 4)
        buf, dst_s, pos, order, tok_s = group_tokens(xt, dst, a_tok, ep, cap_send)
        # payload: local expert id per slot (sentinel E_loc marks empty)
        eid_payload = jnp.full((ep, cap_send), E_loc, jnp.int32)
        eid_payload = eid_payload.at[dst_s, pos].set((a_eid % E_loc)[order].astype(jnp.int32))
        # --- dispatch a2a ------------------------------------------------
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        recv_eid = jax.lax.all_to_all(eid_payload, "model", split_axis=0, concat_axis=0)
        R = ep * cap_send
        rt = recv.reshape(R, d)
        re = recv_eid.reshape(R)
        # --- local expert grouping + FFN ---------------------------------
        cap_e = _round_up(max(int(np.ceil(R * cf / E_loc)), 4), 4)
        gbuf, eid_s2, pos2, order2, tok_s2 = group_tokens(rt, re, jnp.arange(R), E_loc, cap_e)
        y = expert_ffn(gbuf, pg, pu, pd)
        yr = jnp.zeros((R, d), x.dtype)
        ya = y.at[eid_s2, pos2].get(mode="fill", fill_value=0)
        yr = yr.at[tok_s2].add(jnp.where((eid_s2 < E_loc)[:, None], ya, 0))
        # --- return a2a + source-side combine -----------------------------
        back = jax.lax.all_to_all(yr.reshape(ep, cap_send, d), "model", split_axis=0, concat_axis=0)
        out = ungroup_tokens(back, dst_s, pos, T, tok_s, a_w[order])
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))  # replicate for out_spec P()
        return out.reshape(Bl, Sl, d), aux

    fn = _shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P(dp_axes, "model")),
        out_specs=(P(dp_axes, "model"), P()),
        check_vma=False,
    )
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def moe_ffn(cfg: ModelConfig, p: dict, x, mesh: Optional[Mesh]):
    """Dispatch to the right path for (mesh, sequence length)."""
    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1:
        B, S, d = x.shape
        out, aux = _moe_dense(cfg, p, x.reshape(-1, d))
        return out.reshape(B, S, d), aux
    ep = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if cfg.moe.num_experts % ep != 0:
        raise ValueError(f"{cfg.moe.num_experts} experts not divisible by ep={ep}")
    if x.shape[1] % ep == 0 and x.shape[1] >= ep:
        return _moe_ep_seq(cfg, p, x, mesh, dp_axes)
    if cfg.serve_2d:
        return _moe_ep_2d(cfg, p, x, mesh, dp_axes)
    return _moe_ep_replicated(cfg, p, x, mesh, dp_axes)


# ---------------------------------------------------------------------------
# Full model (mirrors transformer.py with MoE FFN + aux loss accumulation)
# ---------------------------------------------------------------------------


def _block_parts(p: dict) -> Tuple[dict, dict]:
    moe_keys = ("router", "w_gate", "w_up", "w_down")
    return (
        {k: v for k, v in p.items() if k not in moe_keys},
        {k: p[k] for k in moe_keys},
    )


def block(cfg: ModelConfig, p: dict, x, positions, mesh):
    base, moe_p = _block_parts(p)
    h = apply_norm(cfg, base["ln1"], x)
    q, k, v = qkv(cfg, base["attn"], h, positions)
    ctx = attend_cfg(cfg, q, k, v, causal=True, window=cfg.sliding_window)
    x = x + attn_out(base["attn"], ctx)
    h = apply_norm(cfg, base["ln2"], x)
    y, aux = moe_ffn(cfg, moe_p, h, mesh)
    return x + y, aux


def forward_train(cfg: ModelConfig, params: dict, batch: dict, mesh=None):
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, pl):
        h, aux = carry
        h, a = block(cfg, pl, h, positions, mesh)
        return (h, aux + a), None

    (x, aux), _ = model_scan(cfg, _remat(cfg, body), (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    return unembed(cfg, params["embed"], x), aux / cfg.num_layers


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return kv_cache_specs(cfg, batch, cache_len, cfg.num_layers)


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int, mesh=None):
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    B, S = x.shape[0], x.shape[1]
    eff = cache_len
    positions = jnp.arange(S)[None, :]

    def body(carry, pl):
        h, aux = carry
        base, moe_p = _block_parts(pl)
        hn = apply_norm(cfg, base["ln1"], h)
        q, k, v = qkv(cfg, base["attn"], hn, positions)
        ctx = attend_cfg(cfg, q, k, v, causal=True)
        h = h + attn_out(base["attn"], ctx)
        hn = apply_norm(cfg, base["ln2"], h)
        y, a = moe_ffn(cfg, moe_p, hn, mesh)
        h = h + y
        if S >= eff:
            kk, vv = k[:, -eff:], v[:, -eff:]
        else:
            pad = [(0, 0), (0, eff - S), (0, 0), (0, 0)]
            kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
        return (h, aux + a), (kk, vv)

    (x, aux), (ks, vs) = model_scan(
        cfg, _remat(cfg, body), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])
    return logits, {"k": ks, "v": vs, "len": jnp.full((B,), S, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict, mesh=None):
    token = batch["token"]
    lengths = cache["len"]
    x = embed(params["embed"], token[:, None])
    positions = lengths[:, None]

    def body(carry, inputs):
        h = carry
        pl, ck, cv = inputs
        base, moe_p = _block_parts(pl)
        hn = apply_norm(cfg, base["ln1"], h)
        q, k, v = qkv(cfg, base["attn"], hn, positions)
        ck, cv = cache_update(ck, cv, k, v, lengths)
        kv_valid = jnp.minimum(lengths + 1, ck.shape[1])
        ctx = attend(q, ck, cv, causal=False, kv_len=kv_valid)
        h = h + attn_out(base["attn"], ctx)
        hn = apply_norm(cfg, base["ln2"], h)
        y, _ = moe_ffn(cfg, moe_p, hn, mesh)
        return h + y, (ck, cv)

    x, (ks, vs) = model_scan(cfg, body, x, (params["blocks"], cache["k"], cache["v"]))
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, {"k": ks, "v": vs, "len": lengths + 1}

"""Whisper-style encoder–decoder (audio backbone; conv frontend is a STUB).

Per the assignment, the modality frontend is stubbed: ``input_specs`` feeds
precomputed mel-frame embeddings [B, enc_positions, d] (what whisper's two
conv layers would produce).  The transformer backbone is exact: pre-LN
LayerNorm blocks, non-gated GELU MLPs, learned positional embeddings, a
full-attention encoder and a causal decoder with per-layer cross attention.

Serving: prefill encodes frames once, caching per-layer cross K/V (the
encoder is never re-run during decode) plus the usual self-attention cache.
The assigned decode shapes (32k cache) exceed whisper's real 448 positions —
we honor the assigned shape; positions are a learned table sized to the
largest assigned shape (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    attend,
    attn_out,
    attn_specs,
    cache_update,
    embed,
    embed_specs,
    mlp_specs,
    norm_spec,
    qkv,
    unembed,
)
from .param import Spec
from .transformer import _remat, model_scan

def specs(cfg: ModelConfig) -> dict:
    assert cfg.encdec is not None
    L, Le, d = cfg.num_layers, cfg.encdec.enc_layers, cfg.d_model
    return {
        "embed": embed_specs(cfg),
        "pos_enc": Spec((cfg.encdec.enc_positions, d), (None, "embed"), scale=0.01),
        "pos_dec": Spec((cfg.encdec.dec_positions, d), (None, "embed"), scale=0.01),
        "enc_blocks": {
            "attn": attn_specs(cfg, stacked=Le),
            "mlp": mlp_specs(cfg, stacked=Le),
            "ln1": norm_spec(cfg, stacked=Le),
            "ln2": norm_spec(cfg, stacked=Le),
        },
        "ln_enc": norm_spec(cfg),
        "dec_blocks": {
            "attn": attn_specs(cfg, stacked=L),
            "xattn": attn_specs(cfg, stacked=L, cross=True),
            "mlp": mlp_specs(cfg, stacked=L),
            "ln1": norm_spec(cfg, stacked=L),
            "lnx": norm_spec(cfg, stacked=L),
            "ln2": norm_spec(cfg, stacked=L),
        },
        "ln_f": norm_spec(cfg),
    }


def encode(cfg: ModelConfig, params: dict, frames):
    """frames: [B, enc_positions, d] stub embeddings → encoder states."""
    x = frames + params["pos_enc"][None, : frames.shape[1]].astype(frames.dtype)

    def body(h, pl):
        hn = apply_norm(cfg, pl["ln1"], h)
        q, k, v = qkv(cfg, pl["attn"], hn, None, use_rope=False)
        h = h + attn_out(pl["attn"], attend(q, k, v, causal=False))
        hn = apply_norm(cfg, pl["ln2"], h)
        return h + apply_mlp(cfg, pl["mlp"], hn), None

    x, _ = model_scan(cfg, _remat(cfg, body), x, params["enc_blocks"])
    return apply_norm(cfg, params["ln_enc"], x)


def _cross_kv(pl: dict, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, pl["xattn"]["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, pl["xattn"]["wv"])
    return k, v


def _dec_block(cfg, pl, x, positions, enc_out=None, xk=None, xv=None, self_kv=None, lengths=None):
    """One decoder block; self_kv/lengths engaged on the decode path."""
    h = apply_norm(cfg, pl["ln1"], x)
    q, k, v = qkv(cfg, pl["attn"], h, None, use_rope=False)
    if self_kv is None:
        x = x + attn_out(pl["attn"], attend(q, k, v, causal=True))
        new_kv = (k, v)
    else:
        ck, cv = cache_update(self_kv[0], self_kv[1], k, v, lengths)
        kv_valid = jnp.minimum(lengths + 1, ck.shape[1])
        x = x + attn_out(pl["attn"], attend(q, ck, cv, causal=False, kv_len=kv_valid))
        new_kv = (ck, cv)
    h = apply_norm(cfg, pl["lnx"], x)
    if xk is None:
        xk, xv = _cross_kv(pl, enc_out)
    qx = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"])
    x = x + attn_out(pl["xattn"], attend(qx, xk, xv, causal=False))
    h = apply_norm(cfg, pl["ln2"], x)
    return x + apply_mlp(cfg, pl["mlp"], h), new_kv


def forward_train(cfg: ModelConfig, params: dict, batch: dict):
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = embed(params["embed"], tokens)
    x = x + params["pos_dec"][None, :S].astype(x.dtype)
    positions = jnp.arange(S)[None, :]

    def body(h, pl):
        h, _ = _dec_block(cfg, pl, h, positions, enc_out=enc_out)
        return h, None

    x, _ = model_scan(cfg, _remat(cfg, body), x, params["dec_blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    return unembed(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    L = cfg.num_layers
    Kv, hd = cfg.padded_kv_heads, cfg.head_dim_
    T = cfg.encdec.enc_positions
    return {
        "k": Spec((L, batch, cache_len, Kv, hd), ("layers", "batch", "seq", "kv_heads", "head_dim")),
        "v": Spec((L, batch, cache_len, Kv, hd), ("layers", "batch", "seq", "kv_heads", "head_dim")),
        "xk": Spec((L, batch, T, Kv, hd), ("layers", "batch", None, "kv_heads", "head_dim")),
        "xv": Spec((L, batch, T, Kv, hd), ("layers", "batch", None, "kv_heads", "head_dim")),
        "len": Spec((batch,), ("batch",), "zeros", dtype="int32"),
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens) + params["pos_dec"][None, :S].astype(enc_out.dtype)
    positions = jnp.arange(S)[None, :]
    eff = cache_len

    def body(h, pl):
        xk, xv = _cross_kv(pl, enc_out)
        h, (k, v) = _dec_block(cfg, pl, h, positions, xk=xk, xv=xv)
        if S >= eff:
            kk, vv = k[:, -eff:], v[:, -eff:]
        else:
            pad = [(0, 0), (0, eff - S), (0, 0), (0, 0)]
            kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
        return h, (kk, vv, xk, xv)

    x, (ks, vs, xks, xvs) = model_scan(cfg, _remat(cfg, body), x, params["dec_blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])
    return logits, {
        "k": ks,
        "v": vs,
        "xk": xks,
        "xv": xvs,
        "len": jnp.full((B,), S, jnp.int32),
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    token = batch["token"]
    lengths = cache["len"]
    x = embed(params["embed"], token[:, None])
    x = x + jnp.take(params["pos_dec"], jnp.minimum(lengths, params["pos_dec"].shape[0] - 1), axis=0)[
        :, None
    ].astype(x.dtype)
    positions = lengths[:, None]

    def body(h, inputs):
        pl, ck, cv, xk, xv = inputs
        h, (ck, cv) = _dec_block(
            cfg, pl, h, positions, xk=xk, xv=xv, self_kv=(ck, cv), lengths=lengths
        )
        return h, (ck, cv)

    x, (ks, vs) = model_scan(
        cfg, body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, {
        "k": ks,
        "v": vs,
        "xk": cache["xk"],
        "xv": cache["xv"],
        "len": lengths + 1,
    }

"""Unified Model facade over the five family implementations.

One object per (config, optional mesh) exposing the API the trainer, server,
dry-run and benchmarks all share:

    m = Model(cfg, mesh)
    m.param_specs()           spec tree (shapes/axes/init in one declaration)
    m.init(rng) / m.shapes()  arrays / ShapeDtypeStructs
    m.loss(params, batch)     → (loss, metrics)
    m.prefill / m.decode_step serving steps
    m.batch_specs(shape)      input Spec tree for an assigned ShapeSpec
    m.cache_specs(shape)      serving-state Spec tree for decode shapes
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import encdec, hybrid, moe, ssm, transformer
from .config import ModelConfig, ShapeSpec
from .layers import xent_loss
from .param import Spec, axes as spec_axes, init as spec_init, shapes as spec_shapes

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "audio": encdec,
}


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.mod = _FAMILY[cfg.family]

    # -- parameters ------------------------------------------------------------
    def param_specs(self):
        return self.mod.specs(self.cfg)

    def shapes(self):
        return spec_shapes(self.param_specs(), self.cfg.dtype)

    def axes(self):
        return spec_axes(self.param_specs())

    def init(self, rng):
        return spec_init(self.param_specs(), rng, self.cfg.dtype)

    # -- training ---------------------------------------------------------------
    def logits(self, params, batch):
        if self.cfg.family == "moe":
            out, aux = self.mod.forward_train(self.cfg, params, batch, mesh=self.mesh)
            return out, aux
        return self.mod.forward_train(self.cfg, params, batch), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.logits(params, batch)
        labels = batch["labels"]
        if self.cfg.vision_tokens:  # loss only over the text positions
            logits = logits[:, self.cfg.vision_tokens :]
        ce = xent_loss(self.cfg, logits, labels)
        total = ce
        if self.cfg.family == "moe":
            total = ce + self.cfg.moe.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving -----------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        if self.cfg.family == "moe":
            return self.mod.prefill(self.cfg, params, batch, cache_len, mesh=self.mesh)
        return self.mod.prefill(self.cfg, params, batch, cache_len)

    def decode_step(self, params, cache, batch):
        if self.cfg.family == "moe":
            return self.mod.decode_step(self.cfg, params, cache, batch, mesh=self.mesh)
        return self.mod.decode_step(self.cfg, params, cache, batch)

    # -- input/cache declarations (drive smoke tests AND the dry-run) -------------
    def batch_specs(self, shape: ShapeSpec) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            out = {
                "tokens": Spec((B, self._text_len(S)), ("batch", "seq"), dtype="int32"),
                "labels": Spec((B, self._text_len(S)), ("batch", "seq"), dtype="int32"),
            }
            self._add_frontend(out, B)
            return out
        if shape.kind == "prefill":
            out = {"tokens": Spec((B, self._text_len(S)), ("batch", "seq"), dtype="int32")}
            self._add_frontend(out, B)
            return out
        # decode: one token against a cache of length S
        return {"token": Spec((B,), ("batch",), dtype="int32")}

    def _text_len(self, S: int) -> int:
        return S - self.cfg.vision_tokens if self.cfg.vision_tokens else S

    def _add_frontend(self, out: dict, B: int) -> None:
        cfg = self.cfg
        if cfg.family == "audio":
            out["frames"] = Spec(
                (B, cfg.encdec.enc_positions, cfg.d_model), ("batch", None, "embed")
            )
        if cfg.vision_tokens:
            out["patch_embeds"] = Spec(
                (B, cfg.vision_tokens, cfg.d_model), ("batch", None, "embed")
            )

    def cache_specs(self, shape: ShapeSpec):
        return self.mod.cache_specs(self.cfg, shape.global_batch, shape.seq_len)

    # -- analytics ----------------------------------------------------------------
    def model_flops_per_token(self) -> int:
        """6·N_active — the §Roofline MODEL_FLOPS convention."""
        return 6 * self.cfg.active_params()

"""Shared neural building blocks: norms, RoPE, GQA attention, gated MLPs.

All functions are pure (params in, arrays out) and jit/scan/shard_map
friendly.  Attention supports causal, sliding-window (SWA), local, cross and
decode-with-cache masking in one code path — the mask offset handles the
"query block sits at the end of a longer KV" decode geometry.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .param import Spec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_spec(cfg: ModelConfig, stacked: Optional[int] = None) -> dict:
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    if cfg.norm == "layernorm":
        return {
            "w": Spec(lead + (cfg.d_model,), lax + ("embed",), "ones"),
            "b": Spec(lead + (cfg.d_model,), lax + ("embed",), "zeros"),
        }
    return {"w": Spec(lead + (cfg.d_model,), lax + ("embed",), "zeros")}


def apply_norm(cfg: ModelConfig, p: dict, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; causal / window / cross / decode)
# ---------------------------------------------------------------------------


def attend(
    q,  # [B, S, H, hd]
    k,  # [B, T, Kv, hd]
    v,  # [B, T, Kv, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: Optional[jnp.ndarray] = None,  # absolute position of q[.,0]
    kv_len: Optional[jnp.ndarray] = None,  # valid prefix length of k/v
):
    """Grouped-query attention with unified masking.

    ``q_offset`` positions the query block inside the key timeline (decode:
    q_offset = cache_len); ``kv_len`` masks cache slots beyond the valid
    prefix.  fp32 softmax for stability.
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qi = jnp.arange(S)[:, None]  # [S, 1]
    kj = jnp.arange(T)[None, :]  # [1, T]
    if q_offset is None:
        off = jnp.asarray(T - S)
    else:
        off = q_offset
    qabs = qi + off  # absolute query positions
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kj <= qabs
    if window is not None:
        mask &= kj > qabs - window
    mask_b = mask[None, :, :]
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        kvl = kvl.reshape(-1, 1, 1) if kvl.ndim else kvl.reshape(1, 1, 1)
        mask_b = mask_b & (kj[None] < kvl)
    scores = jnp.where(mask_b[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def attn_specs(cfg: ModelConfig, stacked: Optional[int] = None, cross: bool = False) -> dict:
    # padded head counts (head_pad_to) let 40/56-head configs shard over the
    # 16-way model axis; pad weights are extra capacity, zero-cost to useful
    # math semantics at init (§Perf hillclimb, EXPERIMENTS.md)
    d, H, Kv, hd = cfg.d_model, cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim_
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    s = {
        "wq": Spec(lead + (d, H, hd), lax + ("embed", "heads", "head_dim")),
        "wk": Spec(lead + (d, Kv, hd), lax + ("embed", "kv_heads", "head_dim")),
        "wv": Spec(lead + (d, Kv, hd), lax + ("embed", "kv_heads", "head_dim")),
        "wo": Spec(lead + (H, hd, d), lax + ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = Spec(lead + (H, hd), lax + ("heads", "head_dim"), "zeros")
        s["bk"] = Spec(lead + (Kv, hd), lax + ("kv_heads", "head_dim"), "zeros")
        s["bv"] = Spec(lead + (Kv, hd), lax + ("kv_heads", "head_dim"), "zeros")
    return s


def qkv(cfg: ModelConfig, p: dict, x, positions=None, use_rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p: dict, ctx):
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


def attend_chunked(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 2048,
):
    """Flash-style attention: scan KV blocks with an online softmax.

    Never materializes the full [S, T] score tensor — the live score block is
    [S, chunk].  This is the jnp analogue of kernels/flash_attention.py (the
    Pallas kernel is the TPU runtime path; this one is what the dry-run
    lowers so the HLO byte counts reflect the blocked structure).
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    if T % chunk:
        chunk = T  # fallback: single block
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    nb = T // chunk
    kb = jnp.moveaxis(k.reshape(B, nb, chunk, Kv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, chunk, Kv, hd), 1, 0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qpos = jnp.arange(S)[:, None] + (T - S)

    def body(carry, blk):
        m, lsum, acc = carry
        kc, vc, j0 = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32) * scale
        kpos = j0 + jnp.arange(chunk)[None, :]
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        lsum = lsum * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, lsum, acc), None

    m0 = jnp.full((B, Kv, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Kv, G, S, hd), jnp.float32)
    offs = jnp.arange(nb) * chunk
    (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, offs))
    out = (acc / jnp.maximum(lsum, 1e-30)[..., None]).astype(q.dtype)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, H, hd)


def attend_cfg(cfg: ModelConfig, q, k, v, *, causal: bool = True, window: Optional[int] = None):
    """Train/prefill attention with the config-selected implementation."""
    if cfg.attn_impl == "chunked" and k.shape[1] > cfg.attn_chunk:
        return attend_chunked(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk)
    return attend(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, stacked: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": Spec(lead + (d, ff), lax + ("embed", "mlp")),
            "w_up": Spec(lead + (d, ff), lax + ("embed", "mlp")),
            "w_down": Spec(lead + (ff, d), lax + ("mlp", "embed")),
        }
    return {
        "w_up": Spec(lead + (d, ff), lax + ("embed", "mlp")),
        "b_up": Spec(lead + (ff,), lax + ("mlp",), "zeros"),
        "w_down": Spec(lead + (ff, d), lax + ("mlp", "embed")),
        "b_down": Spec(lead + (d,), lax + ("embed",), "zeros"),
    }


def apply_mlp(cfg: ModelConfig, p: dict, x):
    if cfg.mlp_type == "swiglu":
        return jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
            * jnp.einsum("bsd,df->bsf", x, p["w_up"]),
            p["w_down"],
        )
    if cfg.mlp_type == "geglu":
        return jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
            * jnp.einsum("bsd,df->bsf", x, p["w_up"]),
            p["w_down"],
        )
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p["b_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]) + p["b_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    V, d = cfg.padded_vocab, cfg.d_model
    s = {"tok": Spec((V, d), ("vocab", "embed"), scale=0.02)}
    if not cfg.tied_embeddings:
        s["head"] = Spec((d, V), ("embed", "vocab"))
    return s


def embed(p: dict, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(cfg: ModelConfig, p: dict, x):
    if cfg.tied_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tok"])
    return jnp.einsum("bsd,dv->bsv", x, p["head"])


def xent_loss(cfg: ModelConfig, logits, labels):
    """Mean cross-entropy over real-vocab logits (padding masked out)."""
    V = cfg.vocab_size
    logits = logits[..., : cfg.padded_vocab]
    pad = logits.shape[-1] - V
    if pad:
        neg = jnp.full((pad,), -1e30, dtype=logits.dtype)
        logits = logits.at[..., V:].set(neg)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# KV cache helpers (decode shapes lower serve_step against these)
# ---------------------------------------------------------------------------


def kv_cache_specs(cfg: ModelConfig, batch: int, cache_len: int, layers: int) -> dict:
    Kv, hd = cfg.padded_kv_heads, cfg.head_dim_
    eff = cache_len if cfg.sliding_window is None else min(cache_len, cfg.sliding_window)
    return {
        "k": Spec((layers, batch, eff, Kv, hd), ("layers", "batch", "seq", "kv_heads", "head_dim")),
        "v": Spec((layers, batch, eff, Kv, hd), ("layers", "batch", "seq", "kv_heads", "head_dim")),
        "len": Spec((batch,), ("batch",), "zeros", dtype="int32"),
    }


def cache_update(cache_k, cache_v, k_new, v_new, lengths, window: Optional[int] = None):
    """Insert one decode step's K/V at position ``lengths`` (ring for SWA)."""
    T = cache_k.shape[1]
    if window is not None:
        idx = lengths % T
    else:
        idx = jnp.minimum(lengths, T - 1)
    b = jnp.arange(cache_k.shape[0])
    cache_k = cache_k.at[b, idx].set(k_new[:, 0])
    cache_v = cache_v.at[b, idx].set(v_new[:, 0])
    return cache_k, cache_v

"""Mamba2 (SSD — state-space duality) attention-free LM.

Block: RMSNorm → {z, x, B, C, dt} projections → causal depthwise conv on
(x|B|C) → SSD chunked scan (kernels.ops.ssd) → gated RMSNorm → out proj.
Decode carries (conv tail, SSM state) — O(1) in sequence length, which is
why this arch runs the long_500k shape.

Sharding: SSD heads over "model" (64 heads / 16 = 4), projections
column/row-parallel, conv channels over "model".
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from .config import ModelConfig
from .layers import embed, embed_specs, norm_spec, rmsnorm, unembed
from .param import Spec
from .transformer import _remat, model_scan


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_ch = di + 2 * s.n_groups * s.d_state
    return di, nh, s.n_groups, s.d_state, conv_ch


def specs(cfg: ModelConfig) -> dict:
    assert cfg.ssm is not None
    L, d = cfg.num_layers, cfg.d_model
    di, nh, G, N, conv_ch = _dims(cfg)
    K = cfg.ssm.d_conv
    return {
        "embed": embed_specs(cfg),
        "blocks": {
            "ln": norm_spec(cfg, stacked=L),
            "wz": Spec((L, d, di), ("layers", "embed", "channels")),
            "wx": Spec((L, d, di), ("layers", "embed", "channels")),
            "wB": Spec((L, d, G * N), ("layers", "embed", "state")),
            "wC": Spec((L, d, G * N), ("layers", "embed", "state")),
            "wdt": Spec((L, d, nh), ("layers", "embed", "ssm_heads")),
            "conv_w": Spec((L, K, conv_ch), ("layers", "conv", "channels")),
            "A_log": Spec((L, nh), ("layers", "ssm_heads"), "ssm_a"),
            "D": Spec((L, nh), ("layers", "ssm_heads"), "ones"),
            "dt_bias": Spec((L, nh), ("layers", "ssm_heads"), "ssm_dt"),
            "norm_g": Spec((L, di), ("layers", "channels"), "zeros"),
            "wo": Spec((L, di, d), ("layers", "channels", "embed")),
        },
        "ln_f": norm_spec(cfg),
    }


def _mix(cfg: ModelConfig, p: dict, h, conv_state=None):
    """Projections + conv; returns (z, xs, Bm, Cm, dt, new conv tail)."""
    di, nh, G, N, conv_ch = _dims(cfg)
    z = jnp.einsum("bsd,de->bse", h, p["wz"])
    xs = jnp.einsum("bsd,de->bse", h, p["wx"])
    Bm = jnp.einsum("bsd,de->bse", h, p["wB"])
    Cm = jnp.einsum("bsd,de->bse", h, p["wC"])
    dtl = jnp.einsum("bsd,dh->bsh", h, p["wdt"])
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc, conv_tail = kops.causal_conv1d(xbc, p["conv_w"], state=conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dtl.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xs, Bm, Cm, dt, conv_tail


def block(cfg: ModelConfig, p: dict, x):
    di, nh, G, N, _ = _dims(cfg)
    B, S, _ = x.shape
    h = rmsnorm(x, p["ln"]["w"])
    z, xs, Bm, Cm, dt, _ = _mix(cfg, p, h)
    y, _ = kops.ssd(
        xs.reshape(B, S, nh, cfg.ssm.head_dim),
        dt,
        p["A_log"],
        Bm.reshape(B, S, G, N),
        Cm.reshape(B, S, G, N),
        p["D"],
        chunk=min(cfg.ssm.chunk, S),
    )
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm_g"])
    return x + jnp.einsum("bse,ed->bsd", y, p["wo"])


def forward_train(cfg: ModelConfig, params: dict, batch: dict):
    x = embed(params["embed"], batch["tokens"])
    body = _remat(cfg, lambda h, pl: (block(cfg, pl, h), None))
    x, _ = model_scan(cfg, body, x, params["blocks"])
    x = rmsnorm(x, params["ln_f"]["w"])
    return unembed(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# Serving: recurrent state instead of a KV cache
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """State is O(1) in cache_len — the whole point of the SSM family."""
    L = cfg.num_layers
    di, nh, G, N, conv_ch = _dims(cfg)
    K = cfg.ssm.d_conv
    return {
        "conv": Spec((L, batch, K - 1, conv_ch), ("layers", "batch", None, "channels"), "zeros"),
        "state": Spec(
            (L, batch, nh, cfg.ssm.head_dim, N),
            ("layers", "batch", "ssm_heads", None, None),
            "zeros",
        ),
        "len": Spec((batch,), ("batch",), "zeros", dtype="int32"),
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    tokens = batch["tokens"]
    di, nh, G, N, _ = _dims(cfg)
    B, S = tokens.shape
    x = embed(params["embed"], tokens)

    def body(h, pl):
        hn = rmsnorm(h, pl["ln"]["w"])
        z, xs, Bm, Cm, dt, conv_tail = _mix(cfg, pl, hn)
        y, st = kops.ssd(
            xs.reshape(B, S, nh, cfg.ssm.head_dim),
            dt,
            pl["A_log"],
            Bm.reshape(B, S, G, N),
            Cm.reshape(B, S, G, N),
            pl["D"],
            chunk=min(cfg.ssm.chunk, S),
        )
        y = y.reshape(B, S, di)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), pl["norm_g"])
        h = h + jnp.einsum("bse,ed->bsd", y, pl["wo"])
        return h, (conv_tail, st.astype(x.dtype))

    x, (convs, states) = model_scan(cfg, _remat(cfg, body), x, params["blocks"])
    x = rmsnorm(x, params["ln_f"]["w"])
    logits = unembed(cfg, params["embed"], x[:, -1:])
    cache = {"conv": convs, "state": states, "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    token = batch["token"]
    di, nh, G, N, _ = _dims(cfg)
    B = token.shape[0]
    x = embed(params["embed"], token[:, None])

    def body(h, inputs):
        pl, conv_st, ssm_st = inputs
        hn = rmsnorm(h, pl["ln"]["w"])
        z, xs, Bm, Cm, dt, conv_tail = _mix(cfg, pl, hn, conv_state=conv_st)
        y, ssm_new = kops.ssd_step(
            ssm_st.astype(jnp.float32),
            xs[:, 0].reshape(B, nh, cfg.ssm.head_dim),
            dt[:, 0],
            pl["A_log"],
            Bm[:, 0].reshape(B, G, N),
            Cm[:, 0].reshape(B, G, N),
            pl["D"],
        )
        y = y.reshape(B, 1, di)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), pl["norm_g"])
        h = h + jnp.einsum("bse,ed->bsd", y, pl["wo"])
        return h, (conv_tail, ssm_new.astype(h.dtype))

    x, (convs, states) = model_scan(cfg, body, x, (params["blocks"], cache["conv"], cache["state"]))
    x = rmsnorm(x, params["ln_f"]["w"])
    logits = unembed(cfg, params["embed"], x)
    return logits, {"conv": convs, "state": states, "len": cache["len"] + 1}

"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (rec, rec, attn) repeats; 26 layers = 8 full groups + 2
trailing recurrent blocks.  Full groups are scanned (stacked params); the
remainder is unrolled — HLO stays O(pattern), not O(depth).

Recurrent block: ln → [gate: W_gate→GeLU] ⊙ [W_x → causal conv1d → RG-LRU]
→ W_o, followed by a GeGLU MLP sub-block.  RG-LRU gates are block-diagonal
(cfg.num_heads blocks), matching RecurrentGemma's parameterization.
Attention block: GQA (kv=1) with RoPE and a local window.

Decode state: per rec block (conv tail [K-1, w], h [w]); per attn block a
ring KV cache of the local window — O(window), which is why this arch runs
long_500k.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from .config import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    attend,
    attn_out,
    attn_specs,
    cache_update,
    embed,
    embed_specs,
    mlp_specs,
    norm_spec,
    qkv,
)
from .layers import unembed
from .param import Spec
from .transformer import _remat, model_scan


def _w(cfg: ModelConfig) -> int:
    return cfg.rglru.width or cfg.d_model


def _nb(cfg: ModelConfig) -> int:
    return max(cfg.num_heads, 1)  # RG-LRU block-diagonal head count


def rec_specs(cfg: ModelConfig, stacked: int = 0) -> dict:
    d, w, nb = cfg.d_model, _w(cfg), _nb(cfg)
    bs = w // nb
    K = cfg.rglru.d_conv
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    return {
        "w_gate": Spec(lead + (d, w), lax + ("embed", "channels")),
        "w_x": Spec(lead + (d, w), lax + ("embed", "channels")),
        "conv_w": Spec(lead + (K, w), lax + ("conv", "channels")),
        "w_r": Spec(lead + (nb, bs, bs), lax + ("channels", None, None)),
        "b_r": Spec(lead + (w,), lax + ("channels",), "zeros"),
        "w_i": Spec(lead + (nb, bs, bs), lax + ("channels", None, None)),
        "b_i": Spec(lead + (w,), lax + ("channels",), "zeros"),
        "lam": Spec(lead + (w,), lax + ("channels",), "lru_a"),
        "w_o": Spec(lead + (w, d), lax + ("channels", "embed")),
    }


def _block_specs(cfg: ModelConfig, kind: str, stacked: int = 0) -> dict:
    s = {
        "ln1": norm_spec(cfg, stacked=stacked or None),
        "ln2": norm_spec(cfg, stacked=stacked or None),
        "mlp": mlp_specs(cfg, stacked=stacked or None),
    }
    if kind == "rec":
        s["rec"] = rec_specs(cfg, stacked)
    else:
        s["attn"] = attn_specs(cfg, stacked=stacked or None)
    return s


def layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(pattern, full groups, remainder kinds)."""
    pat = cfg.rglru.pattern
    g, r = divmod(cfg.num_layers, len(pat))
    return pat, g, pat[:r]


def specs(cfg: ModelConfig) -> dict:
    assert cfg.rglru is not None
    pat, g, rem = layout(cfg)
    return {
        "embed": embed_specs(cfg),
        "groups": {f"b{i}_{kind}": _block_specs(cfg, kind, stacked=g) for i, kind in enumerate(pat)},
        "tail": [_block_specs(cfg, kind) for kind in rem],
        "ln_f": norm_spec(cfg),
    }


# ---------------------------------------------------------------------------
# Block applications
# ---------------------------------------------------------------------------


def _blockdiag(x, W, b):
    """x: [B,S,w] → block-diagonal linear with W [nb, bs, bs]."""
    B, S, w = x.shape
    nb, bs, _ = W.shape
    y = jnp.einsum("bsnk,nkj->bsnj", x.reshape(B, S, nb, bs), W)
    return y.reshape(B, S, w) + b


def rec_mix(cfg: ModelConfig, p: dict, h, state=None):
    """RG-LRU temporal mixer. state: None | (conv_tail, h_rec)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["w_gate"]))
    xs = jnp.einsum("bsd,dw->bsw", h, p["w_x"])
    conv_state = None if state is None else state[0]
    xs, conv_tail = kops.causal_conv1d(xs, p["conv_w"], state=conv_state)
    r = _blockdiag(xs, p["w_r"], p["b_r"])
    i = _blockdiag(xs, p["w_i"], p["b_i"])
    h0 = None if state is None else state[1]
    y, h_last = kops.rglru(xs, r, i, p["lam"], h0=h0)
    y = y * gate
    return jnp.einsum("bsw,wd->bsd", y, p["w_o"]), (conv_tail, h_last)


def apply_block(cfg: ModelConfig, kind: str, p: dict, x, positions):
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rec":
        y, _ = rec_mix(cfg, p["rec"], h)
        x = x + y
    else:
        q, k, v = qkv(cfg, p["attn"], h, positions)
        ctx = attend(q, k, v, causal=True, window=cfg.rglru.local_window)
        x = x + attn_out(p["attn"], ctx)
    h = apply_norm(cfg, p["ln2"], x)
    return x + apply_mlp(cfg, p["mlp"], h)


def forward_train(cfg: ModelConfig, params: dict, batch: dict):
    x = embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    pat, g, rem = layout(cfg)

    def group_body(h, pg):
        for i, kind in enumerate(pat):
            h = apply_block(cfg, kind, pg[f"b{i}_{kind}"], h, positions)
        return h, None

    if g:
        x, _ = model_scan(cfg, _remat(cfg, group_body), x, params["groups"])
    for kind, p in zip(rem, params["tail"]):
        x = apply_block(cfg, kind, p, x, positions)
    x = apply_norm(cfg, params["ln_f"], x)
    return unembed(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def _rec_state_specs(cfg: ModelConfig, batch: int, stacked: int = 0) -> dict:
    w, K = _w(cfg), cfg.rglru.d_conv
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    return {
        "conv": Spec(lead + (batch, K - 1, w), lax + ("batch", None, "channels"), "zeros"),
        "h": Spec(lead + (batch, w), lax + ("batch", "channels"), "zeros"),
    }


def _attn_cache_specs(cfg: ModelConfig, batch: int, stacked: int = 0) -> dict:
    win = cfg.rglru.local_window
    Kv, hd = cfg.padded_kv_heads, cfg.head_dim_
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    return {
        "k": Spec(lead + (batch, win, Kv, hd), lax + ("batch", "seq", "kv_heads", "head_dim"), "zeros"),
        "v": Spec(lead + (batch, win, Kv, hd), lax + ("batch", "seq", "kv_heads", "head_dim"), "zeros"),
    }


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    pat, g, rem = layout(cfg)
    groups = {}
    for i, kind in enumerate(pat):
        groups[f"b{i}_{kind}"] = (
            _rec_state_specs(cfg, batch, stacked=g)
            if kind == "rec"
            else _attn_cache_specs(cfg, batch, stacked=g)
        )
    tail = [
        _rec_state_specs(cfg, batch) if kind == "rec" else _attn_cache_specs(cfg, batch)
        for kind in rem
    ]
    return {
        "groups": groups,
        "tail": tail,
        "len": Spec((batch,), ("batch",), "zeros", dtype="int32"),
    }


def _prefill_block(cfg, kind, p, x, positions, eff):
    """Apply block and return its serving state."""
    S = x.shape[1]
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rec":
        y, (conv_tail, h_last) = rec_mix(cfg, p["rec"], h)
        x = x + y
        st = {"conv": conv_tail, "h": h_last}
    else:
        q, k, v = qkv(cfg, p["attn"], h, positions)
        ctx = attend(q, k, v, causal=True, window=cfg.rglru.local_window)
        x = x + attn_out(p["attn"], ctx)
        if S >= eff:
            kk, vv = k[:, -eff:], v[:, -eff:]
            if S > eff:
                kk = jnp.roll(kk, S % eff, axis=1)
                vv = jnp.roll(vv, S % eff, axis=1)
        else:
            pad = [(0, 0), (0, eff - S), (0, 0), (0, 0)]
            kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
        st = {"k": kk, "v": vv}
    h = apply_norm(cfg, p["ln2"], x)
    return x + apply_mlp(cfg, p["mlp"], h), st


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    eff = min(cache_len, cfg.rglru.local_window)
    x = embed(params["embed"], tokens)
    positions = jnp.arange(S)[None, :]
    pat, g, rem = layout(cfg)

    def group_body(h, pg):
        sts = {}
        for i, kind in enumerate(pat):
            key = f"b{i}_{kind}"
            h, st = _prefill_block(cfg, kind, pg[key], h, positions, eff)
            sts[key] = st
        return h, sts

    groups_cache = {}
    if g:
        x, groups_cache = model_scan(cfg, _remat(cfg, group_body), x, params["groups"])
    tail_cache = []
    for kind, p in zip(rem, params["tail"]):
        x, st = _prefill_block(cfg, kind, p, x, positions, eff)
        tail_cache.append(st)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])
    return logits, {
        "groups": groups_cache,
        "tail": tail_cache,
        "len": jnp.full((B,), S, jnp.int32),
    }


def _decode_block(cfg, kind, p, x, lengths, st):
    positions = lengths[:, None]
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rec":
        y, (conv_tail, h_new) = rec_mix(cfg, p["rec"], h, state=(st["conv"], st["h"]))
        x = x + y
        st = {"conv": conv_tail, "h": h_new}
    else:
        q, k, v = qkv(cfg, p["attn"], h, positions)
        ck, cv = cache_update(st["k"], st["v"], k, v, lengths, cfg.rglru.local_window)
        kv_valid = jnp.minimum(lengths + 1, ck.shape[1])
        ctx = attend(q, ck, cv, causal=False, kv_len=kv_valid)
        x = x + attn_out(p["attn"], ctx)
        st = {"k": ck, "v": cv}
    h = apply_norm(cfg, p["ln2"], x)
    return x + apply_mlp(cfg, p["mlp"], h), st


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    token = batch["token"]
    lengths = cache["len"]
    x = embed(params["embed"], token[:, None])
    pat, g, rem = layout(cfg)

    def group_body(h, inputs):
        pg, cg = inputs
        new = {}
        for i, kind in enumerate(pat):
            key = f"b{i}_{kind}"
            h, st = _decode_block(cfg, kind, pg[key], h, lengths, cg[key])
            new[key] = st
        return h, new

    groups_new = cache["groups"]
    if g:
        x, groups_new = model_scan(cfg, group_body, x, (params["groups"], cache["groups"]))
    tail_new = []
    for kind, p, st in zip(rem, params["tail"], cache["tail"]):
        x, st2 = _decode_block(cfg, kind, p, x, lengths, st)
        tail_new.append(st2)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, {"groups": groups_new, "tail": tail_new, "len": lengths + 1}

"""Parameter spec trees: one declaration drives init, shapes, and sharding.

A model declares its parameters as a pytree of :class:`Spec` leaves.  From
that single declaration we derive:

  * ``init(tree, rng)``     — materialized arrays (smoke tests, real training)
  * ``shapes(tree)``        — ShapeDtypeStructs (dry-run: lower without alloc)
  * ``axes(tree)``          — logical-axis tuples (sharding/partition.py)

This keeps the 10-arch zoo honest: the dry-run lowers exactly the shapes the
trainer would allocate.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | lru_a
    scale: float = 0.02
    dtype: Optional[str] = None  # override model dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def shapes(tree, dtype: str):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or dtype)),
        tree,
        is_leaf=is_spec,
    )


def axes(tree):
    return jax.tree_util.tree_map(lambda s: s.axes, tree, is_leaf=is_spec)


def _init_leaf(s: Spec, key, dtype: str):
    dt = jnp.dtype(s.dtype or dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "lru_a":
        # RG-LRU Λ init: a in [0.9, 0.999] → Λ = softplus^-1(-log(a)/c)
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        c = 8.0
        lam = jnp.log(jnp.expm1(-jnp.log(u) / c))
        return lam.astype(dt)
    if s.init == "ssm_a":
        # Mamba2 A init: -uniform[1, 16], stored as log
        u = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if s.init == "ssm_dt":
        # dt bias ~ softplus^-1(uniform[1e-3, 1e-1])
        u = jax.random.uniform(key, s.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dt)
    return (jax.random.normal(key, s.shape, jnp.float32) * s.scale).astype(dt)


def init(tree, rng, dtype: str):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def count(tree) -> int:
    return sum(
        int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    )

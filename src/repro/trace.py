"""repro.trace — the public in-process user API (≙ Extrae's user API).

Application code talks to the active tracing session through this module:

    import repro.trace as trace

    trace.annotate("epoch_boundary", epoch=3, lr=1e-4)   # one-shot marker
    with trace.phase("warmup"):                          # bracketed phase
        ...
    trace.set_mode("sampled")                            # fidelity ladder

``annotate`` and ``phase`` emit first-class ``ust_user`` records through the
exact ring/stream/fold path traced APIs use — they appear in streams, the
timeline, and (phases) the tally like any other event.  Every call is a
no-op when no session is active (or the rank is unselected), so library code
can annotate unconditionally.

``set_mode`` moves the session along the fidelity ladder —
``"full" | "sampled" | "tally-only" | "off"`` — mid-run with a torn-free
handoff (see :meth:`repro.core.tracer.Tracer.set_mode`).  This is the
escalate-on-trouble lever: run cheap (``tally-only`` or ``sampled``) by
default, flip to ``full`` when something looks wrong, flip back after.
"""

from __future__ import annotations

import contextlib
import json
from typing import Iterator, Optional

from .core.clock import now as _now
from .core.tracepoints import FIDELITY_MODES
from .core.tracer import active_tracer

__all__ = ["FIDELITY_MODES", "annotate", "phase", "set_mode", "get_mode"]


def annotate(name: str, **payload) -> bool:
    """Emit a ``ust_user:annotate`` marker into the active trace.

    ``payload`` keyword arguments are JSON-encoded (sorted keys; non-JSON
    values fall back to ``str``) into the record, so arbitrary context rides
    into the timeline/pretty views.  Returns True when a record was offered
    to the session's ring path, False when there was no active session (or
    tracing is off for this rank) — callers never need to guard.
    """
    tr = active_tracer()
    if tr is None or not tr.selected:
        return False
    rec = tr.tp.record.get("ust_user:annotate")
    if rec is None:  # custom model without the user events
        return False
    rec(name, json.dumps(payload, sort_keys=True, default=str) if payload else "")
    return True


@contextlib.contextmanager
def phase(name: str) -> Iterator[None]:
    """Bracket an application phase as a ``ust_user:phase`` entry/exit pair.

    Folds and tallies exactly like a traced API call (one host row keyed
    ``ust_user:phase``), nests, and is sampled on the "sampled" fidelity
    rung like every other entry/exit pair.  No-op without an active session.
    """
    tr = active_tracer()
    rec = None
    if tr is not None and tr.selected:
        rec = tr.tp.record_pair.get("ust_user:phase")
    if rec is None:
        yield
        return
    ts = _now()
    try:
        yield
    finally:
        # fused pair recorder: (entry name, _ts_entry, exit name)
        rec(name, ts, name)


def set_mode(mode: str) -> str:
    """Move the active session to another fidelity rung; returns the
    previous rung.  Raises ``RuntimeError`` when no session is active and
    ``ValueError`` for an unknown mode."""
    if mode not in FIDELITY_MODES:
        raise ValueError(f"unknown fidelity {mode!r} (want one of {FIDELITY_MODES})")
    tr = active_tracer()
    if tr is None:
        raise RuntimeError("no active tracing session")
    return tr.set_mode(mode)


def get_mode() -> Optional[str]:
    """Current fidelity rung of the active session, or None without one."""
    tr = active_tracer()
    return tr.fidelity if tr is not None else None

from .checkpointer import (  # noqa: F401
    Checkpointer,
    CheckpointManifest,
    latest_checkpoint,
    list_checkpoints,
)

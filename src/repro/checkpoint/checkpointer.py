"""Fault-tolerant checkpointing: atomic, async, integrity-checked, elastic.

Production properties:
  * **atomicity** — writes land in ``step_<n>.tmp/`` and are renamed to
    ``step_<n>/`` only after every leaf + the manifest are durably written;
    a crash mid-save can never corrupt the restore point;
  * **integrity** — the manifest carries per-leaf CRCs and shapes; restore
    validates before handing arrays to the trainer (a truncated file fails
    fast instead of training on garbage);
  * **async commit** — ``save_async`` snapshots to host memory and commits on
    a background thread; the train loop pays host-copy time only;
  * **elastic restore** — leaves are saved as full (unsharded) host arrays;
    restore ``device_put``s against the *current* mesh's NamedSharding, so a
    job restarted on a different device count/mesh reshapes transparently;
  * **retention** — keep the newest ``keep`` checkpoints, never deleting the
    one being written;
  * extra state (data-pipeline step, RNG) rides in the manifest.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.core.interception import checkpoint_restore_span, checkpoint_save_span

_STEP_RE = re.compile(r"^step_(\d+)$")


@dataclasses.dataclass
class CheckpointManifest:
    step: int
    leaves: List[dict]  # [{key, shape, dtype, crc32, nbytes}]
    extra: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "CheckpointManifest":
        return CheckpointManifest(step=int(d["step"]), leaves=d["leaves"], extra=d.get("extra", {}))


def _flatten_with_keys(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def _manifest_ok(path: str) -> bool:
    """Cheap structural validation of a committed checkpoint directory.

    Parses the manifest and checks every referenced leaf file exists with a
    plausible size (at least the payload bytes — the .npy header adds more).
    Full CRC validation stays in :meth:`Checkpointer.restore`; this is the
    fast filter that keeps a corrupted or truncated directory from being
    *selected* as the restore point in the first place.
    """
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            man = CheckpointManifest.from_json(json.load(f))
        for leaf in man.leaves:
            fp = os.path.join(path, leaf["file"])
            if not os.path.isfile(fp) or os.path.getsize(fp) < int(leaf["nbytes"]):
                return False
        return True
    except (OSError, ValueError, KeyError, TypeError):
        return False


def list_checkpoints(root: str) -> List[str]:
    """Structurally-valid checkpoint directories under ``root``, newest first.

    Damaged directories (unparseable manifest, missing or truncated leaf
    files) are skipped — the fall-back chain for restore-after-fault.
    """
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m:
            steps.append((int(m.group(1)), os.path.join(root, name)))
    steps.sort(reverse=True)
    return [path for _, path in steps if _manifest_ok(path)]


def latest_checkpoint(root: str) -> Optional[str]:
    """Newest structurally-valid checkpoint, or None (damaged dirs skipped)."""
    ckpts = list_checkpoints(root)
    return ckpts[0] if ckpts else None


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- save -------------------------------------------------------------------
    def _write(self, step: int, host_leaves: List[Tuple[str, np.ndarray]], extra: dict) -> str:
        final = os.path.join(self.root, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest_leaves = []
        total = 0
        for key, arr in host_leaves:
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            crc = zlib.crc32(arr.tobytes())
            manifest_leaves.append(
                {
                    "key": key,
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": crc,
                    "nbytes": int(arr.nbytes),
                }
            )
            total += arr.nbytes
        man = CheckpointManifest(step=step, leaves=manifest_leaves, extra=extra)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(man.to_json(), f)
        if os.path.exists(final):  # re-save of the same step: replace atomically-enough
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        """Synchronous save. ``tree`` may hold jax or numpy arrays.

        Joins any pending async commit first — a background failure left by
        an earlier :meth:`save_async` surfaces here, not silently after a
        sync save that appeared to succeed.
        """
        self.wait()
        host = [(k, np.asarray(v)) for k, v in _flatten_with_keys(tree)]
        nbytes = sum(a.nbytes for _, a in host)
        with checkpoint_save_span(step, self.root, nbytes):
            return self._write(step, host, extra or {})

    def save_async(self, step: int, tree, extra: Optional[dict] = None) -> None:
        """Snapshot to host, commit in the background. Join via wait().

        A failed background commit is never swallowed: it re-raises from the
        next ``wait()`` *or* the next ``save``/``save_async`` call, whichever
        comes first.
        """
        self.wait()
        host = [(k, np.asarray(v)) for k, v in _flatten_with_keys(tree)]
        nbytes = sum(a.nbytes for _, a in host)

        def commit():
            try:
                with checkpoint_save_span(step, self.root, nbytes):
                    self._write(step, host, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._pending = threading.Thread(target=commit, name="ckpt-commit", daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err

    def _gc(self) -> None:
        steps = sorted(
            int(_STEP_RE.match(n).group(1))
            for n in os.listdir(self.root)
            if _STEP_RE.match(n)
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(
        self, path: str, target_tree, shardings=None
    ) -> Tuple[Any, CheckpointManifest]:
        """Restore into the structure of ``target_tree`` (shapes validated).

        ``shardings``: optional matching pytree of NamedSharding — elastic
        restore onto the current mesh.
        """
        with checkpoint_restore_span(path) as span:
            with open(os.path.join(path, "manifest.json")) as f:
                man = CheckpointManifest.from_json(json.load(f))
            by_key = {leaf["key"]: leaf for leaf in man.leaves}
            keys = [k for k, _ in _flatten_with_keys(target_tree)]
            missing = [k for k in keys if k not in by_key]
            if missing:
                raise ValueError(f"checkpoint missing leaves: {missing[:5]}…")
            leaves = []
            shard_leaves = (
                jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(keys)
            )
            for key, shd in zip(keys, shard_leaves):
                meta = by_key[key]
                arr = np.load(os.path.join(path, meta["file"]))
                if list(arr.shape) != meta["shape"] or zlib.crc32(arr.tobytes()) != meta["crc32"]:
                    raise ValueError(f"checkpoint leaf {key} failed integrity check")
                leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
            treedef = jax.tree_util.tree_structure(target_tree)
            span.outs["step"] = man.step
            return jax.tree_util.tree_unflatten(treedef, leaves), man

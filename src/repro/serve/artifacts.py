"""Sharded prefill/decode step builders — executed by the engine at example
scale and lowered verbatim by the multi-pod dry-run for the inference shapes."""

from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model, ShapeSpec
from repro.models.param import axes as spec_axes, shapes as spec_shapes
from repro.sharding import Partitioner
from repro.train.train_step import _tree_pspecs


def _shardings(partitioner: Partitioner, shapes_tree, axes_tree):
    pspecs = _tree_pspecs(partitioner, shapes_tree, axes_tree)
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(partitioner.mesh, ps), pspecs, is_leaf=lambda x: isinstance(x, P)
    )


def param_artifacts(model: Model, partitioner: Partitioner):
    shapes = model.shapes()
    return shapes, _shardings(partitioner, shapes, model.axes())


def prefill_artifacts(model: Model, partitioner: Partitioner, shape: ShapeSpec):
    """jit + (param, batch) shapes/shardings for the prefill_* shapes.

    Output cache is sharded like cache_specs; logits unconstrained.
    """
    p_shapes, p_shardings = param_artifacts(model, partitioner)
    b_specs = model.batch_specs(shape)
    b_shapes = spec_shapes(b_specs, model.cfg.dtype)
    b_shardings = _shardings(partitioner, b_shapes, spec_axes(b_specs))
    c_specs = model.cache_specs(shape)
    c_shapes = spec_shapes(c_specs, model.cfg.dtype)
    c_shardings = _shardings(partitioner, c_shapes, spec_axes(c_specs))
    fn = jax.jit(
        lambda p, b: model.prefill(p, b, shape.seq_len),
        in_shardings=(p_shardings, b_shardings),
        out_shardings=(None, c_shardings),
    )
    return fn, (p_shapes, b_shapes), (p_shardings, b_shardings)


def decode_artifacts(model: Model, partitioner: Partitioner, shape: ShapeSpec):
    """jit + (params, cache, batch) shapes/shardings for decode_* / long_*.

    serve_step semantics: ONE new token against a cache of shape.seq_len.
    The cache is donated (in-place update in HBM).
    """
    p_shapes, p_shardings = param_artifacts(model, partitioner)
    c_specs = model.cache_specs(shape)
    c_shapes = spec_shapes(c_specs, model.cfg.dtype)
    c_shardings = _shardings(partitioner, c_shapes, spec_axes(c_specs))
    b_specs = model.batch_specs(shape)
    b_shapes = spec_shapes(b_specs, model.cfg.dtype)
    b_shardings = _shardings(partitioner, b_shapes, spec_axes(b_specs))
    fn = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b),
        in_shardings=(p_shardings, c_shardings, b_shardings),
        out_shardings=(None, c_shardings),
        donate_argnums=(1,),
    )
    return fn, (p_shapes, c_shapes, b_shapes), (p_shardings, c_shardings, b_shardings)

"""Batched serving engine: prefill + continuous decode over slot batches.

The engine owns a fixed slot batch (decode efficiency demands static shapes
on TPU).  Requests queue; a slot is (re)filled by running prefill for the
incoming prompt and splicing its cache row into the live batch cache; every
``step()`` decodes one token for all active slots.  Both phases run through
THAPI ``prefill``/``decode_step`` spans — the serving tally of §4.3.

The decode step is a TracedJit with explicit cache shardings (batch over the
data axes, heads over model), donated cache — the same artifact the dry-run
lowers for the decode_32k / long_500k shapes.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.interception import TracedJit, decode_step_span, prefill_span
from repro.models import Model, ShapeSpec
from repro.models.param import axes as spec_axes, init as spec_init, shapes as spec_shapes
from repro.sharding import Partitioner
from repro.train.train_step import _tree_pspecs


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 4
    cache_len: int = 128
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: length-only stopping (synthetic serving)
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        cfg: ServeConfig,
        partitioner: Optional[Partitioner] = None,
        adaptive=None,
        cluster_adaptive=None,
        cluster_credentials: Optional[dict] = None,
    ):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.partitioner = partitioner
        # §6 adaptive consumer: a list of AdaptivePolicy (or a ready
        # AdaptiveController) ticked between decode steps with ctx.engine
        # bound, so policies can reach serving knobs (cfg.max_new_tokens,
        # queue depth) next to the tracing ones. Shares the machinery the
        # tracer's consumer thread uses; requires an online tracing session
        # to observe anything — the controller attaches itself to the active
        # session on first tick, so the Tracer may start before or after
        # engine construction.
        # cluster_adaptive: ClusterPolicy list (or ready controller) ticked
        # the same way; reads the per-rank map of the session's in-process
        # master (TraceConfig.serve_port), so a serving frontend can watch
        # for straggling backends streaming into it.
        from repro.core.adaptive import build_cluster_controller, build_controller

        # cluster_credentials: {"addr": ..., "token": ..., "tls_ca": ...}
        # forwarded to the cluster controller so it can reach a hardened
        # (token-auth / TLS) master instead of only the in-process one.
        self.adaptive = build_controller(adaptive)
        self.cluster_adaptive = build_cluster_controller(
            cluster_adaptive, **(cluster_credentials or {})
        )
        self._rid = itertools.count()
        B = cfg.batch_slots
        shape = ShapeSpec("serve", "decode", cfg.cache_len, B)
        cache_specs = model.cache_specs(shape)
        self._cache_shapes = spec_shapes(cache_specs, model.cfg.dtype)
        cache_shardings = None
        if partitioner is not None:
            pspecs = _tree_pspecs(partitioner, self._cache_shapes, spec_axes(cache_specs))
            cache_shardings = jax.tree_util.tree_map(
                lambda ps: NamedSharding(partitioner.mesh, ps),
                pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
        self.cache = spec_init(cache_specs, jax.random.PRNGKey(0), model.cfg.dtype)
        self.cache = jax.tree_util.tree_map(jnp.zeros_like, self.cache)
        if cache_shardings is not None:
            self.cache = jax.device_put(self.cache, cache_shardings)
        self._decode = TracedJit(
            lambda p, c, b: model.decode_step(p, c, b),
            name=f"decode_step[{model.cfg.name}]",
            donate_argnums=(1,),
            out_shardings=(None, cache_shardings),
            flops=2 * model.cfg.active_params() * B,
        )
        self.slots: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self._tok = jnp.zeros((B,), jnp.int32)
        self._prefill_jits: Dict[int, TracedJit] = {}

    # -- request intake -----------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> Request:
        r = Request(rid=next(self._rid), prompt=np.asarray(prompt, np.int32))
        self.queue.append(r)
        return r

    def _fill_slots(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            r = self.queue.pop(0)
            self._prefill_into(i, r)
            self.slots[i] = r

    def _prefill_into(self, slot: int, r: Request) -> None:
        """Prefill a single prompt, splice its cache row into the live batch."""
        toks = r.prompt[None, :]
        with prefill_span(r.rid, 1, int(toks.shape[1])):
            batch = {"tokens": jnp.asarray(toks)}
            if self.model.cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, self.model.cfg.encdec.enc_positions, self.model.cfg.d_model),
                    self.cache_dtype(),
                )
            S = int(toks.shape[1])
            if S not in self._prefill_jits:  # one compile per prompt length
                self._prefill_jits[S] = TracedJit(
                    lambda p, b: self.model.prefill(p, b, self.cfg.cache_len),
                    name=f"prefill[{self.model.cfg.name}/S{S}]",
                )
            logits, row = self._prefill_jits[S](self.params, batch)
        first = int(jnp.argmax(logits[0, 0, : self.model.cfg.vocab_size]))
        r.out_tokens.append(first)
        self._tok = self._tok.at[slot].set(first)
        self.cache = jax.tree_util.tree_map(
            lambda c, v: self._splice(c, v, slot), self.cache, row
        )

    def cache_dtype(self):
        return jnp.bfloat16 if self.model.cfg.dtype == "bfloat16" else jnp.float32

    @staticmethod
    def _splice(cache_leaf, row_leaf, slot: int):
        """Insert the size-1-batch prefill row at slot. Batch axis is the one
        where the shapes differ (layers lead; batch follows)."""
        for ax in range(cache_leaf.ndim):
            if row_leaf.shape[ax] == 1 and cache_leaf.shape[ax] != 1:
                idx = [slice(None)] * cache_leaf.ndim
                idx[ax] = slice(slot, slot + 1)
                return cache_leaf.at[tuple(idx)].set(row_leaf.astype(cache_leaf.dtype))
        # scalar-per-batch leaves (e.g. len)
        return cache_leaf.at[slot].set(row_leaf.reshape(-1)[0].astype(cache_leaf.dtype))

    # -- decode loop -----------------------------------------------------------------
    def step(self) -> int:
        """One batched decode step; returns #active slots."""
        self._fill_slots()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        rid = self.slots[active[0]].rid
        with decode_step_span(rid, len(active), self.cfg.cache_len) as sp:
            logits, self.cache = self._decode(self.params, self.cache, {"token": self._tok})
            nxt = jnp.argmax(
                logits[:, 0, : self.model.cfg.vocab_size], axis=-1
            ).astype(jnp.int32)
            sp.outs["tokens_out"] = len(active)
        self._tok = nxt
        if self.adaptive is not None:
            self.adaptive.tick(engine=self)
        if self.cluster_adaptive is not None:
            self.cluster_adaptive.tick()
        host = np.asarray(nxt)
        for i in active:
            r = self.slots[i]
            r.out_tokens.append(int(host[i]))
            if len(r.out_tokens) >= self.cfg.max_new_tokens or int(host[i]) == self.cfg.eos_id:
                r.done = True
                self.completed.append(r)
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.completed

    # -- live profile (§3.7+§6 streaming service) ------------------------------
    def live_tally(self):
        """Live tally of the surrounding tracing session, or None.

        Requires the engine to run under ``Tracer(TraceConfig(online=True))``
        (or any streaming knob, which implies it).  With ``serve_port`` set
        the session runs an in-process master, so this is the *global*
        composite — the prefill/decode spans of this server merged with
        every rank streaming into it.
        """
        from repro.core.stream import live_snapshot

        return live_snapshot()

    def live_profile(self, top: Optional[int] = None) -> Optional[str]:
        """Rendered live tally (the §4.3 table) for /profile-style endpoints."""
        from repro.core.plugins.tally import render

        t = self.live_tally()
        return None if t is None else render(t, top=top)

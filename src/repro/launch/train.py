"""Training launcher: ``--arch <id>`` selects an assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
        --steps 20 --trace default --sample

Full configs target the production mesh (use the dry-run on CPU); --smoke
runs the reduced config on the local mesh end-to-end.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import ARCHS, get_config
from repro.jaxcompat import make_mesh
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.models import Model, ShapeSpec
from repro.sharding import Partitioner
from repro.train import TrainConfig, Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config on the local mesh")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--trace", choices=["off", "minimal", "default", "full"], default="off")
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--trace-dir", default="/tmp/thapi_train")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    elif len(jax.devices()) < 16:
        print(
            f"[train] full {args.arch} needs the production mesh; "
            "use --smoke here or repro.launch.dryrun for the 256/512-chip lowering",
            file=sys.stderr,
        )
        return 2

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    model = Model(cfg, mesh)
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    trainer = Trainer(
        model,
        shape,
        Partitioner(mesh, fsdp=cfg.fsdp),
        TrainConfig(
            peak_lr=args.lr,
            warmup=max(2, args.steps // 10),
            total_steps=max(args.steps, 10),
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
        ),
        TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 2, 1), ckpt_dir=args.ckpt_dir),
    )
    tracer = None
    if args.trace != "off":
        tracer = Tracer(
            TraceConfig(out_dir=args.trace_dir, mode=args.trace, sample=args.sample)
        ).start()
    try:
        res = trainer.run()
    finally:
        if tracer is not None:
            tracer.stop()
    h = res["history"]
    print(f"{args.arch}: loss {h[0]['loss']:.3f} → {h[-1]['loss']:.3f} in {res['steps_run']} steps")
    if tracer is not None:
        print(render(tally_trace(args.trace_dir), top=8))
    return 0


if __name__ == "__main__":
    sys.exit(main())

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  512 placeholder host devices back the 2×16×16 production mesh; the
# dry-run lowers + compiles every (arch × shape × mesh) cell with
# ShapeDtypeStructs — no arrays are ever allocated.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × its applicable shapes) × {single-pod 16×16,
multi-pod 2×16×16}:

    with mesh:
        lowered = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system.  Results land as JSON in --out for EXPERIMENTS.md
§Dry-run/§Roofline and benchmarks/roofline.py.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both -o results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k --mesh single
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import List, Optional, Tuple

import jax

from repro.configs import ARCHS, get_config
from repro.launch.mesh import describe, make_production_mesh
from repro.models import Model, SHAPES, applicable_shapes
from repro.sharding import Partitioner


def cells(arch_filter: str, shape_filter: str, mesh_filter: str) -> List[Tuple[str, str, bool]]:
    out = []
    archs = ARCHS if arch_filter == "all" else [arch_filter]
    for arch in archs:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            if shape_filter != "all" and shape.name != shape_filter:
                continue
            for multi in (False, True):
                if mesh_filter == "single" and multi:
                    continue
                if mesh_filter == "multi" and not multi:
                    continue
                out.append((arch, shape.name, multi))
    return out


def _lower_and_compile(cfg, shape, mesh, part, microbatches: int = 1):
    """Lower + compile the production step for one (cfg, shape, mesh)."""
    model = Model(cfg, mesh)
    with mesh:
        if shape.kind == "train":
            from repro.train.train_step import TrainConfig, build_train_artifacts

            tcfg = TrainConfig(adamw=_adamw_for(cfg), microbatches=microbatches)
            step, state_shapes, _, batch_shapes, _ = build_train_artifacts(
                model, part, shape, tcfg
            )
            lowered = step.jit.lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            from repro.serve.artifacts import prefill_artifacts

            fn, shapes, _ = prefill_artifacts(model, part, shape)
            lowered = fn.lower(*shapes)
        else:
            from repro.serve.artifacts import decode_artifacts

            fn, shapes, _ = decode_artifacts(model, part, shape)
            lowered = fn.lower(*shapes)
        return lowered, lowered.compile()


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    cfg_override=None,
    extrapolate_depth: bool = True,
    microbatches: int = 1,
) -> dict:
    from repro.launch import roofline as rl
    from repro.models.config import depth_units, with_depth

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg.serve_2d and shape.kind == "decode":
        part = Partitioner(mesh, mode="serve2d")  # resident 2D expert weights
    else:
        part = Partitioner(mesh, fsdp=cfg.fsdp)
    t0 = time.monotonic()
    # 1) full-depth compile: THE proof that the production step lowers,
    #    shards and fits (memory analysis) on this mesh.
    lowered, compiled = _lower_and_compile(cfg, shape, mesh, part, microbatches)
    t_compile = time.monotonic() - t0
    mem_stats = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"--- {arch} × {shape_name} × {describe(mesh)} ---")
        print("memory_analysis:", mem_stats)
        print(
            "cost_analysis:",
            {k: cost[k] for k in ("flops", "bytes accessed") if k in cost},
        )
    # 2) roofline terms: unrolled 1-unit / 2-unit depth compiles +
    #    linear extrapolation (XLA cost analysis counts loop bodies once).
    units = depth_units(cfg)
    if extrapolate_depth and units >= 2:
        _, c1 = _lower_and_compile(with_depth(cfg, 1), shape, mesh, part, microbatches)
        _, c2 = _lower_and_compile(with_depth(cfg, 2), shape, mesh, part, microbatches)
        meas = rl.extrapolate(rl.measure(c1), rl.measure(c2), units)
    else:
        meas = rl.measure(compiled)
    rf = rl.roofline_from(
        meas,
        rl.model_flops_for(cfg, shape, mesh.size),
        rl.memory_stats(compiled),
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": describe(mesh),
        "multi_pod": multi_pod,
        "n_devices": mesh.size,
        "ok": True,
        "t_compile_s": round(t_compile, 2),
        "t_total_s": round(time.monotonic() - t0, 2),
        "scan_body_once_flops": float(cost.get("flops", 0.0)),
        "roofline": rf.to_json(),
    }
    if verbose:
        print(
            f"terms: compute={rf.t_compute:.4f}s memory={rf.t_memory:.4f}s "
            f"collective={rf.t_collective:.4f}s → {rf.bottleneck}-bound; "
            f"MODEL/HLO flops={rf.useful_flops_ratio:.3f} "
            f"roofline_fraction={rf.roofline_fraction:.3f}"
        )
    return result


def _adamw_for(cfg):
    from repro.optim import AdamWConfig

    # 1T-param config: bf16 optimizer state to approach the HBM budget
    return AdamWConfig(state_dtype="bfloat16" if cfg.fsdp else "float32")


def _result_path(out_dir: str, arch: str, shape: str, multi: bool) -> str:
    mesh = "pod2x16x16" if multi else "pod16x16"
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", choices=["all"] + ARCHS)
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("-o", "--out", default=None, help="write per-cell JSON here")
    ap.add_argument("--jobs", type=int, default=1, help="parallel worker processes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    todo = cells(args.arch, args.shape, args.mesh)
    if args.list:
        for c in todo:
            print(*c)
        return 0
    if args.out:
        os.makedirs(args.out, exist_ok=True)

    if args.jobs > 1:
        return _parallel(todo, args)

    failures = 0
    for arch, shape, multi in todo:
        path = _result_path(args.out, arch, shape, multi) if args.out else None
        if path and args.skip_existing and os.path.exists(path):
            continue
        try:
            # roofline extrapolation only on the single-pod mesh (the
            # §Roofline table is single-pod; multi-pod is the compile proof)
            res = run_cell(arch, shape, multi, extrapolate_depth=not multi)
        except Exception as e:  # a failing cell is a bug — record it loudly
            traceback.print_exc()
            res = {
                "arch": arch,
                "shape": shape,
                "multi_pod": multi,
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        if path:
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    print(f"dry-run: {len(todo) - failures}/{len(todo)} cells compiled")
    return 1 if failures else 0


def _parallel(todo, args) -> int:
    """Spawn one subprocess per cell (compile isolation + parallelism)."""
    pending = []
    failures = 0
    idx = 0
    done = 0
    while done < len(todo):
        while len(pending) < args.jobs and idx < len(todo):
            arch, shape, multi = todo[idx]
            idx += 1
            path = _result_path(args.out, arch, shape, multi) if args.out else None
            if path and args.skip_existing and os.path.exists(path):
                done += 1
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape,
                "--mesh", "multi" if multi else "single",
            ]
            if args.out:
                cmd += ["-o", args.out]
            p = subprocess.Popen(cmd)
            pending.append(((arch, shape, multi), p))
        time.sleep(0.5)
        still = []
        for cell, p in pending:
            if p.poll() is None:
                still.append((cell, p))
            else:
                done += 1
                if p.returncode != 0:
                    failures += 1
                    print(f"[dryrun] FAILED: {cell}")
        pending = still
    print(f"dry-run: {len(todo) - failures}/{len(todo)} cells compiled")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Elastic rank replacement: spawn, restore, and splice workers into a live mesh.

PR 9's remediation ladder ends at eviction — a sick rank is drained,
terminated, and its remainder re-dealt to survivors, permanently shrinking
the mesh.  At scale node failure is a steady-state condition, so the loop
must close: this module spawns a *replacement incarnation* of the evicted
logical rank, restores it from the latest undamaged checkpoint, and splices
it back via :meth:`repro.launch.mesh.RemeshPlan.splice_rank` (the
replacement claws back exactly the un-done re-dealt remainder — work
conservation is preserved through evict → splice as an identity).

Two layers, both process-model-agnostic (any handle with ``poll`` /
``terminate`` / ``kill`` / ``wait`` works — ``subprocess.Popen``, a fake in
tests, a scheduler shim on a real cluster):

* :class:`WorkerSupervisor` — owns the worker handles and the per-rank
  **incarnation counter**.  Every spawn of a logical rank gets a strictly
  larger incarnation; the streaming tier fences frames from superseded
  incarnations (docs/streaming.md §incarnations), so a zombie of the old
  process can never corrupt the composite no matter how late its frames
  arrive.
* :class:`ReplacementManager` — the policy layer the remediation engine's
  ``replace`` hook drives: pick the restore point, terminate the old
  incarnation, spawn the new one (capped retries), wait for it to become
  ready, and compute the splice.  Every spawn / admit / give-up decision is
  reported through ``on_event`` — wired to
  :meth:`repro.core.remediation.RemediationEngine.note`, the decisions land
  in the audit log and the trace as ``ust_repro:remediation`` events like
  any other rung.

Nothing here touches jax device state (module contract shared with
``launch/mesh.py``): checkpoint *discovery* is manifest-reading only; the
replacement process itself restores device state on its side of the fence.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Callable, Dict, Optional, Tuple

from repro.launch.mesh import RemeshPlan

__all__ = [
    "WorkerSupervisor",
    "ReplacementManager",
    "ReplacementResult",
    "latest_restorable_step",
]

_STEP_RE = re.compile(r"^step_(\d+)$")

#: ``on_event`` callback signature: (action, target, detail, ok)
EventFn = Callable[[str, str, str, bool], None]


def latest_restorable_step(ckpt_root: str) -> Optional[Tuple[str, int]]:
    """Newest structurally-sound checkpoint under ``ckpt_root``.

    Returns ``(path, step)`` or None.  Mirrors the checkpointer's
    newest-first damaged-dir skip (parseable manifest, every leaf file
    present at full payload size) without importing the jax-backed
    checkpoint package — replacement *planning* must stay runnable on a
    driver host with no accelerator stack.
    """
    if not os.path.isdir(ckpt_root):
        return None
    steps = []
    for name in os.listdir(ckpt_root):
        m = _STEP_RE.match(name)
        if m:
            steps.append((int(m.group(1)), os.path.join(ckpt_root, name)))
    for step, path in sorted(steps, reverse=True):
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                man = json.load(f)
            ok = all(
                os.path.isfile(os.path.join(path, leaf["file"]))
                and os.path.getsize(os.path.join(path, leaf["file"]))
                >= int(leaf["nbytes"])
                for leaf in man["leaves"]
            )
        except (OSError, ValueError, KeyError, TypeError):
            continue
        if ok:
            return path, int(man.get("step", step))
    return None


class WorkerSupervisor:
    """Owns worker process handles and the per-rank incarnation counter.

    ``spawn`` is the launch callable: ``spawn(rank, incarnation) -> handle``
    where the handle quacks like ``subprocess.Popen`` (``poll()`` → None
    while alive, ``terminate()``, ``kill()``, ``wait(timeout)``).  The
    supervisor never invents incarnation numbers out of thin air: rank r's
    first registration is incarnation 0 (the original launch) and every
    :meth:`spawn_replacement` bumps it by one — strictly monotone per rank,
    which is exactly what the master's fencing relies on.
    """

    def __init__(self, spawn: Callable[[int, int], object]):
        self._spawn = spawn
        self._handles: Dict[int, object] = {}
        self._incarnation: Dict[int, int] = {}

    def register(self, rank: int, handle: object, incarnation: int = 0) -> None:
        """Adopt an already-running worker (the original launch path)."""
        self._handles[int(rank)] = handle
        self._incarnation[int(rank)] = int(incarnation)

    def handle(self, rank: int) -> Optional[object]:
        return self._handles.get(int(rank))

    def incarnation(self, rank: int) -> int:
        """Current incarnation of ``rank`` (0 = original, never spawned)."""
        return self._incarnation.get(int(rank), 0)

    def alive(self, rank: int) -> bool:
        h = self._handles.get(int(rank))
        return h is not None and h.poll() is None

    def ranks(self) -> Tuple[int, ...]:
        return tuple(sorted(self._handles))

    def terminate(self, rank: int, timeout_s: float = 5.0) -> None:
        """Best-effort stop of the current incarnation: TERM, wait, KILL.

        Idempotent and tolerant of already-dead processes — the common case
        *is* a dead process (that is why it is being replaced)."""
        h = self._handles.get(int(rank))
        if h is None:
            return
        try:
            if h.poll() is None:
                h.terminate()
                try:
                    h.wait(timeout=timeout_s)
                except Exception:
                    h.kill()
                    try:
                        h.wait(timeout=timeout_s)
                    except Exception:
                        pass
        except Exception:
            pass

    def spawn_replacement(self, rank: int) -> Tuple[object, int]:
        """Launch the next incarnation of ``rank``; returns (handle, inc).

        The incarnation is bumped *before* the spawn, so even a spawn that
        dies instantly has burned its number — a later retry gets a fresh
        one and the fence stays strictly monotone."""
        inc = self._incarnation.get(int(rank), 0) + 1
        self._incarnation[int(rank)] = inc
        handle = self._spawn(int(rank), inc)
        self._handles[int(rank)] = handle
        return handle, inc


@dataclasses.dataclass(frozen=True)
class ReplacementResult:
    """Outcome of one :meth:`ReplacementManager.replace` attempt chain."""

    ok: bool
    rank: int
    incarnation: int          # incarnation admitted (or last one attempted)
    restored_step: int        # checkpoint step the replacement restores from (-1 = none)
    checkpoint: Optional[str]  # restore-point path (None = fresh start)
    plan: Optional[RemeshPlan]  # post-splice topology (None on failure)
    giveback: Dict[int, int]  # survivor id → steps clawed back
    attempts: int             # spawn attempts consumed
    detail: str


class ReplacementManager:
    """Spawn-restore-splice policy for the remediation ``replace`` rung.

    Parameters:

    * ``supervisor`` — the :class:`WorkerSupervisor` owning the handles;
    * ``ckpt_root_for`` — rank → checkpoint root directory (None: the
      replacement starts fresh and the restore point is reported as -1);
    * ``ready`` — ``(rank, incarnation) -> bool`` admission predicate,
      polled until True or ``ready_timeout_s``.  This is where the driver
      checks "the master has seen a frame from the new incarnation" /
      "the worker ack'd its restore" — whatever *ready* means for the
      deployment.  None admits as soon as the process is alive;
    * ``spawn_retries`` — extra spawn attempts after the first (a chain of
      ``1 + spawn_retries`` attempts before giving up — the remediation
      engine then falls through to plain eviction);
    * ``on_event`` — decision sink (``RemediationEngine.note``): every
      spawn, admit, and give-up is observable, per the audit invariant.
    """

    def __init__(
        self,
        supervisor: WorkerSupervisor,
        *,
        ckpt_root_for: Optional[Callable[[int], str]] = None,
        ready: Optional[Callable[[int, int], bool]] = None,
        ready_timeout_s: float = 30.0,
        poll_s: float = 0.1,
        spawn_retries: int = 2,
        on_event: Optional[EventFn] = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        if ready_timeout_s <= 0 or poll_s <= 0:
            raise ValueError("ready_timeout_s and poll_s must be > 0")
        if spawn_retries < 0:
            raise ValueError("spawn_retries must be >= 0")
        self.supervisor = supervisor
        self.ckpt_root_for = ckpt_root_for
        self.ready = ready
        self.ready_timeout_s = ready_timeout_s
        self.poll_s = poll_s
        self.spawn_retries = spawn_retries
        self.on_event = on_event
        self.clock = clock
        self.sleep = sleep
        self.spawned = 0   # spawn attempts issued
        self.admitted = 0  # replacements that reached ready + splice
        self.failed = 0    # replace() calls that gave up

    def _note(self, action: str, target: str, detail: str, ok: bool = True) -> None:
        if self.on_event is not None:
            try:
                self.on_event(action, target, detail, ok)
            except Exception:
                pass  # observability must never break the replacement

    def restore_point(self, rank: int) -> Tuple[Optional[str], int]:
        """(checkpoint path, step) the replacement of ``rank`` restores from."""
        if self.ckpt_root_for is None:
            return None, -1
        found = latest_restorable_step(self.ckpt_root_for(int(rank)))
        if found is None:
            return None, -1
        return found

    def _await_ready(self, rank: int, inc: int, handle: object) -> Tuple[bool, str]:
        deadline = self.clock() + self.ready_timeout_s
        while True:
            rc = None
            try:
                rc = handle.poll()
            except Exception:
                pass
            if rc is not None:
                return False, f"replacement died during startup (exit {rc})"
            if self.ready is None or self.ready(rank, inc):
                return True, ""
            if self.clock() >= deadline:
                return False, f"not ready within {self.ready_timeout_s:.1f}s"
            self.sleep(self.poll_s)

    def replace(
        self,
        rank: int,
        plan: RemeshPlan,
        dealt: Dict[int, int],
        done_extra: Optional[Dict[int, int]] = None,
        reason: str = "",
        target: Optional[str] = None,
    ) -> ReplacementResult:
        """Run the full spawn → ready → splice chain for ``rank``.

        ``plan`` is the post-eviction topology; ``dealt`` the shares its
        ``reassign`` handed each survivor (``plan.deal_shares``);
        ``done_extra`` how much of those shares is already finished.  On
        success the returned plan has ``rank`` spliced back in and
        ``giveback`` says exactly what each survivor returns.  On failure
        (spawn chain exhausted) ``ok=False`` — the caller (the remediation
        engine's replace hook) falls through to plain eviction.
        """
        tgt = target if target is not None else f"rank{rank}"
        ckpt, step = self.restore_point(rank)
        last_inc = self.supervisor.incarnation(rank)
        attempts = 0
        detail = ""
        for attempt in range(1 + self.spawn_retries):
            self.supervisor.terminate(rank)
            handle, inc = self.supervisor.spawn_replacement(rank)
            last_inc = inc
            attempts += 1
            self.spawned += 1
            self._note(
                "replace_spawn",
                tgt,
                f"incarnation {inc} attempt {attempts} restore step {step}"
                + (f" ({reason})" if reason else ""),
            )
            ok, detail = self._await_ready(rank, inc, handle)
            if ok:
                new_plan, giveback = plan.splice_rank(rank, dealt, done_extra)
                self.admitted += 1
                clawed = sum(giveback.values())
                self._note(
                    "replace_admit",
                    tgt,
                    f"incarnation {inc} spliced, {clawed} steps clawed back "
                    f"from {len(giveback)} survivors",
                )
                return ReplacementResult(
                    ok=True,
                    rank=rank,
                    incarnation=inc,
                    restored_step=step,
                    checkpoint=ckpt,
                    plan=new_plan,
                    giveback=giveback,
                    attempts=attempts,
                    detail="admitted",
                )
            self._note(
                "replace_spawn", tgt, f"incarnation {inc} failed: {detail}", ok=False
            )
            self.supervisor.terminate(rank)
        self.failed += 1
        self._note(
            "replace_giveup",
            tgt,
            f"gave up after {attempts} spawn attempts: {detail}",
            ok=False,
        )
        return ReplacementResult(
            ok=False,
            rank=rank,
            incarnation=last_inc,
            restored_step=step,
            checkpoint=ckpt,
            plan=None,
            giveback={},
            attempts=attempts,
            detail=detail or "spawn attempts exhausted",
        )

"""Serving launcher (smoke scale): batched requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --requests 8 --trace full
"""

from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import TraceConfig, Tracer
from repro.core.plugins.tally import render, tally_trace
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--trace", choices=["off", "minimal", "default", "full"], default="off")
    ap.add_argument("--trace-dir", default="/tmp/thapi_serve")
    args = ap.parse_args(argv)

    model = Model(get_config(args.arch).smoke())
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(
        model,
        params,
        ServeConfig(
            batch_slots=args.slots, cache_len=args.cache_len, max_new_tokens=args.new_tokens
        ),
    )
    rng = np.random.default_rng(0)
    tracer = None
    if args.trace != "off":
        tracer = Tracer(TraceConfig(out_dir=args.trace_dir, mode=args.trace)).start()
    try:
        for _ in range(args.requests):
            eng.submit(rng.integers(0, model.cfg.vocab_size, size=(args.prompt_len,)))
        done = eng.run_until_drained()
    finally:
        if tracer is not None:
            tracer.stop()
    print(f"served {len(done)} requests, {sum(len(r.out_tokens) for r in done)} tokens")
    if tracer is not None:
        print(render(tally_trace(args.trace_dir), top=10))
    return 0


if __name__ == "__main__":
    sys.exit(main())

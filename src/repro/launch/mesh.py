"""Production mesh construction.

Importing this module never touches jax device state — meshes are built by
FUNCTIONS only (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 chips per pod; 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices are actually present (examples/trainer)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    if n % mp:
        raise ValueError(f"{n} devices not divisible by model_parallel={mp}")
    return make_mesh((n // mp, mp), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())


# -- elastic re-mesh (remediation rung 3) -----------------------------------------
#
# When the remediation ladder evicts a sick rank, the surviving ranks need a
# new dense rank assignment and the evicted rank's unfinished work needs new
# owners.  These helpers are pure functions over *logical* rank ids — the
# driver applies the plan by relaunching / re-configuring workers; nothing
# here touches jax device state (module contract above).


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    """Survivor topology after evicting ranks from a world of ``world_size``.

    ``survivors`` keeps original rank ids in order; ``dense_rank`` maps each
    survivor's original id to its new dense id (0..len(survivors)-1), which
    is what data-parallel sharding keys off after the re-mesh.
    """

    world_size: int
    evicted: Tuple[int, ...]
    survivors: Tuple[int, ...]
    dense_rank: Dict[int, int]

    def reassign(self, pending: Dict[int, int]) -> Dict[int, int]:
        """Fold evicted ranks' pending work onto the survivors.

        ``pending`` maps original rank id → count of unfinished work items
        (steps, shards...).  Survivors keep their own count; each evicted
        rank's count is dealt round-robin across survivors (orphan work is
        spread, not dumped on rank 0).  Returns original-survivor-id → new
        count; total work is conserved.
        """
        if not self.survivors:
            raise ValueError("no survivors to reassign work to")
        out = {r: int(pending.get(r, 0)) for r in self.survivors}
        orphans = sorted(
            (r, int(n)) for r, n in pending.items() if r in set(self.evicted)
        )
        i = 0
        for _, n in orphans:
            for _ in range(n):
                out[self.survivors[i % len(self.survivors)]] += 1
                i += 1
        return out

    def deal_shares(self, rank: int, remainder: int) -> Dict[int, int]:
        """How :meth:`reassign` would deal ``rank``'s ``remainder`` across
        the survivors: survivor id → share.  The record a driver must keep
        at eviction time so a later :meth:`splice_rank` can claw exactly the
        re-dealt work back (work conservation is an identity over these
        shares, not a re-derivation)."""
        if rank not in set(self.evicted):
            raise ValueError(f"rank {rank} is not evicted in this plan")
        shared = self.reassign({rank: int(remainder)})
        return {s: shared[s] for s in self.survivors if shared[s]}

    def splice_rank(
        self,
        rank: int,
        dealt: Dict[int, int],
        done_extra: Optional[Dict[int, int]] = None,
    ) -> Tuple["RemeshPlan", Dict[int, int]]:
        """Splice an evicted ``rank`` back into the mesh (its replacement).

        ``dealt`` is the share of the evicted rank's remainder each survivor
        was handed at eviction time (:meth:`deal_shares`); ``done_extra``
        is how much of that share each survivor has *already finished* —
        finished work is never clawed back.  Returns ``(new_plan,
        giveback)`` where ``giveback`` maps survivor id → steps returned to
        the replacement: ``max(0, dealt - done_extra)``.  The replacement
        takes back exactly the un-done remainder, so total work across the
        mesh is conserved through evict → splice regardless of how far each
        survivor got (the chaos harness asserts this identity end-to-end).
        """
        ev = set(self.evicted)
        if rank not in ev:
            raise ValueError(f"rank {rank} is not evicted in this plan")
        done = done_extra or {}
        giveback: Dict[int, int] = {}
        for s, share in dealt.items():
            if s not in self.dense_rank:
                raise ValueError(f"dealt share names non-survivor rank {s}")
            back = max(0, int(share) - int(done.get(s, 0)))
            if back:
                giveback[s] = back
        ev.discard(rank)
        surv = tuple(sorted(set(self.survivors) | {rank}))
        return (
            RemeshPlan(
                world_size=self.world_size,
                evicted=tuple(sorted(ev)),
                survivors=surv,
                dense_rank={r: i for i, r in enumerate(surv)},
            ),
            giveback,
        )


def plan_eviction(world_size: int, evicted: Iterable[int]) -> RemeshPlan:
    """Build the survivor re-mesh plan for evicting ``evicted`` ranks.

    Evicting every rank (or an unknown rank id) is a planning error and
    raises — the remediation engine's eviction budget should have stopped
    the ladder before the cluster ate itself.
    """
    ev = tuple(sorted(set(int(r) for r in evicted)))
    if any(r < 0 or r >= world_size for r in ev):
        raise ValueError(f"evicted ranks {ev} out of range for world_size={world_size}")
    surv = tuple(r for r in range(world_size) if r not in set(ev))
    if not surv:
        raise ValueError(f"cannot evict all {world_size} ranks")
    return RemeshPlan(
        world_size=world_size,
        evicted=ev,
        survivors=surv,
        dense_rank={r: i for i, r in enumerate(surv)},
    )

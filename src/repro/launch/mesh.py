"""Production mesh construction.

Importing this module never touches jax device state — meshes are built by
FUNCTIONS only (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 chips per pod; 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices are actually present (examples/trainer)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    if n % mp:
        raise ValueError(f"{n} devices not divisible by model_parallel={mp}")
    return make_mesh((n // mp, mp), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())

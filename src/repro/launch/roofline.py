"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (§Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = collective_bytes_per_device / link_bw      (~50 GB/s ICI)

``compiled.cost_analysis()`` supplies per-device FLOPs/bytes (the compiled
module IS the per-device program after SPMD partitioning).  Collective bytes
are NOT in cost_analysis — we parse the partitioned HLO text and apply
ring-cost conventions per op kind:

    all-reduce        2 × tensor bytes   (reduce-scatter + all-gather phases)
    all-gather        result bytes       (each device receives ≈ the result)
    reduce-scatter    operand bytes      (each device sends ≈ the operand)
    all-to-all        tensor bytes
    collective-permute  tensor bytes

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (inference) convention with
N = active parameters; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat /
redundant-compute waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

# e.g. "bf16[256,1024]{1,0}" or "f32[]"; tuples handled by finditer
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f16|c64)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9\[\],{}()\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Ring-cost collective bytes per device from partitioned HLO text."""
    counts: Dict[str, int] = {}
    by_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[1][:60]:
            # *-done ops re-state the shape of the matching *-start; skip
            if not m:
                continue
        kind = m.group(2)
        if f"{kind}-done" in line:
            continue
        # HLO: %name = TYPE[shape] op(TYPE[shape] %operand, ...)
        _, _, rhs = line.partition("=")
        head, _, args = rhs.partition("(")
        result_b = _shape_bytes(head)
        operand_b = _shape_bytes(args)
        if kind == "all-reduce":
            b = 2 * result_b
        elif kind == "all-gather":
            b = result_b
        elif kind == "reduce-scatter":
            b = operand_b or result_b
        else:  # all-to-all / collective-permute
            b = max(result_b, operand_b)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + b
    return CollectiveStats(counts, by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_counts: Dict[str, int]
    coll_by_kind: Dict[str, int]
    model_flops: float
    # memory_analysis
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / bound time — the score we hillclimb."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return (self.model_flops / PEAK_FLOPS) / bound if bound else 0.0

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "coll_counts": self.coll_counts,
            "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "arg_bytes": self.arg_bytes,
            "out_bytes": self.out_bytes,
            "temp_bytes": self.temp_bytes,
            "peak_bytes": self.peak_bytes,
        }


def measure(compiled) -> dict:
    """Raw per-device measures from one compiled artifact."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
        "coll_counts": dict(coll.counts),
        "coll_by_kind": dict(coll.bytes_by_kind),
    }


def extrapolate(m1: dict, m2: dict, units: float) -> dict:
    """Linear depth extrapolation: cost(U) = m1 + (U-1)·(m2-m1).

    m1/m2 come from UNROLLED 1-unit / 2-unit depth compiles (XLA's cost
    analysis counts a while-loop body once, so the scanned full-depth compile
    under-reports; unrolled small-depth compiles measure true per-layer cost
    and the stack is homogeneous by construction).
    """
    out = {}
    for key in ("flops", "bytes_accessed", "coll_bytes"):
        per = m2[key] - m1[key]
        out[key] = m1[key] + (units - 1.0) * per
    out["coll_counts"] = {
        k: int(round(m1["coll_counts"].get(k, 0) + (units - 1.0) * (m2["coll_counts"].get(k, 0) - m1["coll_counts"].get(k, 0))))
        for k in set(m1["coll_counts"]) | set(m2["coll_counts"])
    }
    out["coll_by_kind"] = {
        k: int(round(m1["coll_by_kind"].get(k, 0) + (units - 1.0) * (m2["coll_by_kind"].get(k, 0) - m1["coll_by_kind"].get(k, 0))))
        for k in set(m1["coll_by_kind"]) | set(m2["coll_by_kind"])
    }
    return out


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "arg_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "out_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            ),
        }
    except Exception:
        return {}


def roofline_from(meas: dict, model_flops: float, mem: dict) -> "Roofline":
    return Roofline(
        flops=meas["flops"],
        bytes_accessed=meas["bytes_accessed"],
        coll_bytes=meas["coll_bytes"],
        coll_counts=meas["coll_counts"],
        coll_by_kind=meas["coll_by_kind"],
        model_flops=model_flops,
        **mem,
    )


def model_flops_for(cfg, shape, n_devices: int) -> float:
    """Per-device useful FLOPs per step (6ND train / 2ND inference)."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        total = 6 * n_active * shape.tokens
    elif shape.kind == "prefill":
        total = 2 * n_active * shape.tokens
    else:  # decode: one token per sequence
        total = 2 * n_active * shape.global_batch
    return total / n_devices


def analyze(compiled, cfg, shape, n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "arg_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "out_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
            ),
        }
    except Exception:
        pass
    return Roofline(
        flops=flops,
        bytes_accessed=nbytes,
        coll_bytes=float(coll.total_bytes),
        coll_counts=coll.counts,
        coll_by_kind=coll.bytes_by_kind,
        model_flops=model_flops_for(cfg, shape, n_devices),
        **mem,
    )

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver — the three chosen cells, hypothesis → change →
measure → validate (methodology in EXPERIMENTS.md §Perf).

Cells (from the §Roofline baseline table):
  A qwen1.5-32b × train_4k      — worst useful-flops ratio among train cells
                                  (0.18): 40 heads don't divide the 16-way
                                  model axis → attention entirely unsharded.
  B kimi-k2-1t-a32b × decode_32k — most collective-bound cell (7.8 s vs
                                  2.2 s memory): FSDP re-gathers 1T of expert
                                  weights every decode step.
  C mistral-large-123b × train_4k — most representative production cell
                                  (flagship dense train; best baseline 18%).

    PYTHONPATH=src python -m repro.launch.perf --cell all -o results/perf
"""

import argparse
import dataclasses
import json
import sys
from typing import Callable, Dict, List, Optional

from repro.configs import get_config
from repro.launch.dryrun import run_cell


def _v(name: str, hypothesis: str, prediction: str, transform: Callable, **extra):
    return dict(
        name=name, hypothesis=hypothesis, prediction=prediction, transform=transform, **extra
    )


CELLS: Dict[str, dict] = {
    "A": {
        "arch": "qwen1.5-32b",
        "shape": "train_4k",
        "variants": [
            _v(
                "headpad16",
                "40 q/kv heads % 16 ≠ 0 ⇒ attention weights+activations are "
                "replicated over the model axis; every device computes all 40 "
                "heads and materializes full [S,S] scores. Padding heads to 48 "
                "(3/device) shards attention 16-ways.",
                "memory term ≈ ÷10 (score bytes 43 TB→3.2 TB/device ×bwd); "
                "compute term ↓ similarly; roofline fraction 2.7% → >15%",
                lambda c: dataclasses.replace(c, head_pad_to=16),
            ),
            _v(
                "headpad16+chunked",
                "Dense attention materializes [S,S] f32 scores in several "
                "passes (softmax, mask, bwd). A KV-block online-softmax scan "
                "(flash-style) keeps only [S,chunk] alive.",
                "memory term ↓ further ~1.5–2×; compute ~flat",
                lambda c: dataclasses.replace(
                    c, head_pad_to=16, attn_impl="chunked", attn_chunk=1024
                ),
            ),
        ],
    },
    "B": {
        "arch": "kimi-k2-1t-a32b",
        "shape": "decode_32k",
        "variants": [
            _v(
                "serve2d",
                "FSDP shards 2 TB of expert weights over (data×model) and "
                "all-gathers them EVERY decode step (~390 GB/device of "
                "collectives for 128 tokens). Keeping weights resident in a "
                "2D layout (experts×model, expert-FFN×data) and moving the "
                "1.8 MB of activations instead inverts the ratio.",
                "collective term 7.8 s → <0.05 s; memory term becomes the "
                "weight-read bound (~8 GB/device ⇒ ~10 ms); bound flips to "
                "memory, roofline fraction ≫ baseline",
                lambda c: dataclasses.replace(c, serve_2d=True),
            ),
        ],
    },
    "C": {
        "arch": "mistral-large-123b",
        "shape": "train_4k",
        "variants": [
            _v(
                "chunked",
                "96 heads / 16 = 6/device are already TP-sharded, but dense "
                "attention still materializes [S,S] f32 scores per head "
                "(16×6×4096²×4 B ≈ 6.4 TB/device per pass). Chunked online "
                "softmax removes the full materialization.",
                "memory term 83 s → ~55 s; compute flat; fraction 18% → ~27%",
                lambda c: dataclasses.replace(c, attn_impl="chunked", attn_chunk=1024),
            ),
            _v(
                "chunked+remat_micro8",
                "Baseline peak HBM 860 GB/device ⇒ doesn't fit 16 GB. Full "
                "remat + 8 microbatches cuts live activations ~8× at ~+33% "
                "recompute FLOPs — fit is a hard constraint at this scale.",
                "peak_bytes ≈ ÷8–20 (toward fitting); compute term +≤33%; "
                "memory term similar or ↓ (smaller live set)",
                lambda c: dataclasses.replace(
                    c, attn_impl="chunked", attn_chunk=1024, remat="full"
                ),
                microbatches=8,
            ),
        ],
    },
}


def run_cell_variants(cell_key: str, out_dir: Optional[str]) -> List[dict]:
    cell = CELLS[cell_key]
    arch, shape = cell["arch"], cell["shape"]
    results = []
    base_cfg = get_config(arch)
    print(f"=== cell {cell_key}: {arch} × {shape} ===")
    base = run_cell(arch, shape, multi_pod=False, cfg_override=base_cfg)
    base["variant"] = "baseline"
    results.append(base)
    for v in cell["variants"]:
        print(f"\n--- variant {v['name']} ---")
        print("hypothesis:", v["hypothesis"])
        print("prediction:", v["prediction"])
        cfg = v["transform"](base_cfg)
        res = run_cell(
            arch,
            shape,
            multi_pod=False,
            cfg_override=cfg,
            microbatches=v.get("microbatches", 1),
        )
        res["variant"] = v["name"]
        res["hypothesis"] = v["hypothesis"]
        res["prediction"] = v["prediction"]
        b, n = base["roofline"], res["roofline"]
        res["delta"] = {
            "t_compute": n["t_compute"] / max(b["t_compute"], 1e-12),
            "t_memory": n["t_memory"] / max(b["t_memory"], 1e-12),
            "t_collective": n["t_collective"] / max(b["t_collective"], 1e-12),
            "roofline_fraction": n["roofline_fraction"] / max(b["roofline_fraction"], 1e-12),
            "peak_bytes": n.get("peak_bytes", 0) / max(b.get("peak_bytes", 1), 1),
        }
        print("delta vs baseline:", {k: round(x, 3) for k, x in res["delta"].items()})
        results.append(res)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"cell_{cell_key}.json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["all", "A", "B", "C"])
    ap.add_argument("-o", "--out", default="results/perf")
    args = ap.parse_args(argv)
    keys = list(CELLS) if args.cell == "all" else [args.cell]
    for k in keys:
        run_cell_variants(k, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())

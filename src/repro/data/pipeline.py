"""Deterministic sharded synthetic data pipeline.

Production properties the trainer depends on:
  * **determinism & restorability** — batch at step t is a pure function of
    (seed, step, dp_rank); the iterator state is just the step counter, saved
    inside every checkpoint, so restarts resume mid-epoch exactly;
  * **sharding** — each dp rank generates only its local slice; the trainer
    device_puts slices against the global batch NamedSharding;
  * **host prefetch** — a background thread keeps ``prefetch`` batches ready
    so the accelerator never waits on generation (overlap compute/host);
  * **frontend stubs** — audio frames / VLM patch embeddings are generated to
    the model's ``batch_specs`` (the assignment's stub-frontend contract).

Synthetic text follows a Zipf-ish distribution with induced bigram structure
so cross-entropy actually decreases during the example runs (pure uniform
tokens would pin loss at ln V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models import Model, ShapeSpec


@dataclasses.dataclass
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    #: batches kept ready by the prefetch thread
    prefetch: int = 2


class SyntheticPipeline:
    """Iterator of host numpy batches for (model, shape, dp shard)."""

    def __init__(
        self,
        model: Model,
        shape: ShapeSpec,
        cfg: Optional[DataConfig] = None,
        dp_rank: int = 0,
        dp_size: int = 1,
        start_step: int = 0,
    ):
        self.model = model
        self.shape = shape
        self.cfg = cfg or DataConfig()
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.step = start_step
        self.specs = model.batch_specs(shape)
        if shape.global_batch % dp_size:
            raise ValueError(f"global_batch {shape.global_batch} % dp {dp_size} != 0")
        self.local_batch = shape.global_batch // dp_size
        # Zipf-ish unigram table over the real vocab
        V = model.cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = ranks ** (-self.cfg.zipf_a)
        self._probs = p / p.sum()
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- deterministic generation ------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.dp_rank])
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        V = self.model.cfg.vocab_size
        out: Dict[str, np.ndarray] = {}
        for name, spec in self.specs.items():
            shape = (self.local_batch,) + spec.shape[1:]
            if spec.dtype == "int32":
                toks = rng.choice(V, size=shape, p=self._probs).astype(np.int32)
                if name == "tokens" and len(shape) == 2 and shape[1] > 1:
                    # induce learnable bigram structure: even positions repeat
                    toks[:, 1::2] = (toks[:, 0::2][:, : toks[:, 1::2].shape[1]] + 1) % V
                out[name] = toks
            else:
                out[name] = (rng.standard_normal(shape) * 0.1).astype(np.float32)
        if "labels" in self.specs:
            out["labels"] = np.roll(out["tokens"], -1, axis=1)
        return out

    # -- iterator protocol w/ prefetch ---------------------------------------------
    def _worker(self):
        assert self._q is not None
        step = self.step
        while not self._stop.is_set():
            b = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self) -> "SyntheticPipeline":
        self._q = queue.Queue(maxsize=self.cfg.prefetch)
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, name="data-prefetch", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._q is None:
            b = self.batch_at(self.step)
            self.step += 1
            return b
        step, b = self._q.get()
        self.step = step + 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    # -- checkpointable state ---------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed, "dp_rank": self.dp_rank}

    def load_state_dict(self, d: dict) -> None:
        if d.get("seed", self.cfg.seed) != self.cfg.seed:
            raise ValueError("restoring data state with a different seed")
        was_running = self._q is not None
        if was_running:
            self.stop()
        self.step = int(d["step"])
        if was_running:
            self.start()


def make_eval_batch(model: Model, shape: ShapeSpec, seed: int = 7) -> Dict[str, np.ndarray]:
    pipe = SyntheticPipeline(model, shape, DataConfig(seed=seed))
    return pipe.batch_at(0)

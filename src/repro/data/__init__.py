from .pipeline import DataConfig, SyntheticPipeline, make_eval_batch  # noqa: F401

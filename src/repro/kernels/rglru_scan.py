"""Pallas TPU RG-LRU scan (RecurrentGemma/Griffin recurrence).

TPU adaptation: the recurrence h_t = a_t·h_{t-1} + b_t is sequential in time
but embarrassingly parallel over channels.  The kernel tiles channels into
128-lane VMEM blocks (grid dim 1) and walks the sequence with a fori_loop,
keeping h resident in VREGs — the TPU-idiomatic replacement for a GPU warp
scan.  Gate math (softplus/sigmoid/exp) is fused into the same kernel so a/b
never round-trip to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, r_ref, i_ref, lam_ref, h0_ref, y_ref, hN_ref, *, seq: int, c: float):
    lam = lam_ref[0, :].astype(jnp.float32)  # [blk_c]
    # fused gate math
    log_a = (
        -c
        * jax.nn.softplus(lam)[None, :]
        * jax.nn.sigmoid(r_ref[0].astype(jnp.float32))
    )  # [S, blk_c]
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_ref[0].astype(jnp.float32)) * x_ref[0].astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq, step, h0_ref[0, :].astype(jnp.float32))
    hN_ref[0, :] = h


def _largest_divisor(n: int, cap: int) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("blk_c", "interpret", "c"))
def rglru_pallas(x, r, i, lam, h0=None, *, blk_c: int = 128, c: float = 8.0, interpret: bool = False):
    """x, r, i: [B, S, C]; lam: [C]; h0: [B, C] or None → (y [B,S,C], h_last [B,C])."""
    B, S, C = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    blk_c = _largest_divisor(C, blk_c)
    grid = (B, C // blk_c)
    kern = functools.partial(_kernel, seq=S, c=c)
    y, hN = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, blk_c), lambda b, ci: (b, 0, ci)),
            pl.BlockSpec((1, S, blk_c), lambda b, ci: (b, 0, ci)),
            pl.BlockSpec((1, S, blk_c), lambda b, ci: (b, 0, ci)),
            pl.BlockSpec((1, blk_c), lambda b, ci: (0, ci)),
            pl.BlockSpec((1, blk_c), lambda b, ci: (b, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, blk_c), lambda b, ci: (b, 0, ci)),
            pl.BlockSpec((1, blk_c), lambda b, ci: (b, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), x.dtype),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        interpret=interpret,
    )(x, r, i, lam[None, :], h0)
    return y, hN

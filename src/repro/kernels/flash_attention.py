"""Pallas TPU flash attention (causal/windowed GQA).

TPU-native adaptation (not a CUDA port): the grid's innermost dimension
iterates KV blocks sequentially while q/m/l/acc live in VMEM scratch — the
online-softmax accumulator pattern that keeps the working set in VMEM and
feeds the MXU [blk_q × d] · [d × blk_k] tiles (d = head_dim = 128 on every
assigned arch ⇒ lane-aligned).  GQA is handled in the index maps: the KV
block index is ``h // (H // Kv)``, so no KV replication in memory.

Block sizes default to 128×128 (MXU-native); the wrapper shrinks them to the
largest divisor for small test shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    blk_q: int,
    blk_k: int,
    nk: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    scale: float,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # [blk_q, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [blk_k, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(2)
    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0) + q_offset
    kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        lsum = l_scr[...]
        # fully-masked rows (can't happen for causal q_offset>=0, but keep safe)
        denom = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _largest_divisor(n: int, cap: int) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret")
)
def flash_attention_pallas(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
):
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    blk_q = _largest_divisor(S, blk_q)
    blk_k = _largest_divisor(T, blk_k)
    nq, nk = S // blk_q, T // blk_k
    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel,
        blk_q=blk_q,
        blk_k=blk_k,
        nk=nk,
        causal=causal,
        window=window,
        q_offset=T - S,
        scale=1.0 / (hd**0.5),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` is the semantic definition; kernel tests sweep shapes/dtypes
and assert allclose against these.  The model zoo calls kernels.ops, which
dispatches to these refs on CPU and to the Pallas kernels on TPU.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Flash attention (causal / windowed GQA)
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None
):
    """Materialized-scores attention. q:[B,S,H,d] k/v:[B,T,Kv,d] → [B,S,H,d]."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    qi = jnp.arange(S)[:, None] + (T - S)
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", p, v).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


def rglru_scan_ref(a, b, h0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t ⊙ h_{t-1} + b_t over axis 1. a,b: [B,S,C] → (h, h_last).

    Associative formulation — on TPU this parallelizes (log-depth) instead of
    the GPU-style sequential warp scan (DESIGN.md hardware adaptation).
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (af, bf), axis=1)
    return h.astype(a.dtype), h[:, -1]


def rglru_gates_ref(x, r, i, lam, c: float = 8.0):
    """RG-LRU gate math: a_t = exp(-c·softplus(Λ)·σ(r_t)); b_t = √(1-a²)·(σ(i_t)·x_t)."""
    log_a = -c * jax.nn.softplus(lam.astype(jnp.float32)) * jax.nn.sigmoid(
        r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_ref(x, r, i, lam, h0=None, c: float = 8.0):
    a, b = rglru_gates_ref(x, r, i, lam, c)
    h, h_last = rglru_scan_ref(a, b, h0)
    return h.astype(x.dtype), h_last


def rglru_step_ref(h, x_t, r_t, i_t, lam, c: float = 8.0):
    """Single decode step: returns (y_t, h')."""
    a, b = rglru_gates_ref(x_t[:, None], r_t[:, None], i_t[:, None], lam, c)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------


def ssd_ref(x, dt, A_log, Bm, Cm, D, chunk: int = 64, state0=None):
    """Chunked SSD. Shapes:
      x: [B,S,H,P]  dt: [B,S,H] (post-softplus)  A_log: [H]
      Bm, Cm: [B,S,G,N]  D: [H]
    Returns (y [B,S,H,P], final state [B,H,P,N]).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    assert S % chunk == 0, f"seq {S} must divide chunk {chunk}"
    nc = S // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))  # [H]
    a = dt.astype(jnp.float32) * A  # [B,S,H] (log-decay per step)

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, Pd)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    af = a.reshape(Bsz, nc, chunk, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    # broadcast groups → heads
    Bh = jnp.repeat(Bf, hpg, axis=3)  # [B,nc,L,H,N]
    Ch = jnp.repeat(Cf, hpg, axis=3)

    cum = jnp.cumsum(af, axis=2)  # [B,nc,L,H]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,i,j,H]
    ii, jj = jnp.meshgrid(jnp.arange(chunk), jnp.arange(chunk), indexing="ij")
    LT = jnp.where((jj <= ii)[None, None, :, :, None], jnp.exp(seg), 0.0)
    # intra-chunk: y[i] = Σ_j C_i·B_j · L[i,j] · dt_j · x_j
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)  # [B,nc,i,j,H]
    W = CB * LT * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xf)
    # chunk-boundary states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,L,H]
    chunk_state = jnp.einsum(
        "bclh,bclhn,bclhp->bchpn", dtf * decay_to_end, Bh, xf
    )  # [B,nc,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    s0 = (
        jnp.zeros((Bsz, H, Pd, N), jnp.float32)
        if state0 is None
        else state0.astype(jnp.float32)
    )

    def body(carry, inp):
        st = carry
        cs, cd = inp  # [B,H,P,N], [B,H]
        new = st * cd[:, :, None, None] + cs
        return new, st  # emit state *entering* this chunk

    chunk_states = jnp.moveaxis(chunk_state, 1, 0)
    chunk_decays = jnp.moveaxis(chunk_decay, 1, 0)
    final, entering = jax.lax.scan(body, s0, (chunk_states, chunk_decays))
    entering = jnp.moveaxis(entering, 0, 1)  # [B,nc,H,P,N]
    # inter-chunk contribution: y[i] += exp(cum_i) · C_i · state_entering
    y_inter = jnp.einsum(
        "bclh,bclhn,bchpn->bclhp", jnp.exp(cum), Ch, entering
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_step_ref(state, x_t, dt_t, A_log, B_t, C_t, D):
    """Single decode step.
      state: [B,H,P,N]  x_t: [B,H,P]  dt_t: [B,H]  B_t/C_t: [B,G,N]
    Returns (y_t [B,H,P], state').
    """
    H = x_t.shape[1]
    G = B_t.shape[1]
    hpg = H // G
    A = -jnp.exp(A_log.astype(jnp.float32))
    da = jnp.exp(dt_t.astype(jnp.float32) * A)  # [B,H]
    Bh = jnp.repeat(B_t.astype(jnp.float32), hpg, axis=1)  # [B,H,N]
    Ch = jnp.repeat(C_t.astype(jnp.float32), hpg, axis=1)
    xb = jnp.einsum(
        "bh,bhp,bhn->bhpn", dt_t.astype(jnp.float32), x_t.astype(jnp.float32), Bh
    )
    state = state.astype(jnp.float32) * da[:, :, None, None] + xb
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + x_t.astype(jnp.float32) * D.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (SSM/RG-LRU temporal conv)
# ---------------------------------------------------------------------------


def causal_conv1d_ref(x, w, state=None):
    """x: [B,S,C], w: [K,C] depthwise causal conv.
    state: [B,K-1,C] trailing context (decode). Returns (y, new_state)."""
    K = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        if state is None
        else state.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return y.astype(x.dtype), xp[:, -(K - 1) :] if K > 1 else pad

"""Pallas TPU Mamba2 SSD (state-space duality) chunked scan.

TPU adaptation of the SSD algorithm: per (batch, head) the sequence is cut
into chunks; within a chunk the quadratic "attention-like" form runs on the
MXU ([chunk × N] · [N × chunk] and [chunk × chunk] · [chunk × P] tiles), and
the O(1) inter-chunk state [P × N] is carried in VMEM scratch across the
innermost grid dimension — the recurrence never leaves the core.  chunk=128,
P=64/128, N=128 keep every matmul dimension lane/MXU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, y_ref, st_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [L]
    A = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # [L, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # [L, N]
    D = d_ref[0].astype(jnp.float32)

    a = dt * A  # [L] log-decay
    cum = jnp.cumsum(a)  # [L]
    # intra-chunk quadratic form (lower triangular)
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    LT = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [L, L]
    W = CB * LT * dt[None, :]
    y = jnp.dot(W, x, preferred_element_type=jnp.float32)  # [L, P]
    # inter-chunk: contribution of the state entering this chunk
    st = st_scr[...]  # [P, N]
    y += jnp.exp(cum)[:, None] * jnp.dot(Cm, st.T, preferred_element_type=jnp.float32)
    # state update for the next chunk
    decay_to_end = jnp.exp(cum[-1] - cum)  # [L]
    st_scr[...] = st * jnp.exp(cum[-1]) + jnp.dot(
        (x * (dt * decay_to_end)[:, None]).T, Bm, preferred_element_type=jnp.float32
    )
    y += x * D
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A_log, Bm, Cm, D, *, chunk: int = 128, state0=None, interpret: bool = False):
    """Shapes as ssd_ref. state0 unsupported in-kernel (train path starts at 0);
    returns (y, final_state) with final_state recomputed functionally."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    nc = S // chunk
    grid = (B, H, nc)
    kern = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h // hpg, 0)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, Bm, Cm, D)
    # final state: cheap O(S) reduction done outside the kernel
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = dt.astype(jnp.float32) * A[None, None, :]
    cum_total = jnp.cumsum(a, axis=1)
    decay_to_end = jnp.exp(cum_total[:, -1:, :] - cum_total)  # [B,S,H]
    Bh = jnp.repeat(Bm.astype(jnp.float32), hpg, axis=2)
    final = jnp.einsum(
        "bsh,bshn,bshp->bhpn",
        dt.astype(jnp.float32) * decay_to_end,
        Bh,
        x.astype(jnp.float32),
    )
    if state0 is not None:
        final += state0.astype(jnp.float32) * jnp.exp(cum_total[:, -1, :])[..., None, None]
    return y, final

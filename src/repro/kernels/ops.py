"""jit-ready dispatch wrappers for the Pallas kernels.

Selection policy: the Pallas kernels target TPU; on this CPU container they
run under ``interpret=True`` (validated in tests), while the default runtime
path uses the jnp references — numerically identical, fast on CPU, and the
dry-run lowers the same einsum structure XLA:TPU fuses well.

Set ``impl="pallas"`` (or REPRO_KERNELS=pallas) to force the kernels; every
wrapper also emits a THAPI ``ust_kernel:launch`` span with analytic FLOPs and
bytes so traced runs attribute device time to the hot spots.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interception import kernel_span

from . import ref as _ref


def _impl(impl: Optional[str]) -> str:
    if impl is not None:
        return impl
    env = os.environ.get("REPRO_KERNELS", "")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None, impl=None):
    B, S, H, hd = q.shape
    T = k.shape[1]
    flops = 4 * B * H * S * T * hd // (2 if causal else 1)
    bytes_accessed = sum(int(np.prod(t.shape)) * t.dtype.itemsize for t in (q, k, v)) * 2
    with kernel_span("flash_attention", (B, H, S), flops, bytes_accessed):
        if _impl(impl) == "pallas":
            from .flash_attention import flash_attention_pallas

            return flash_attention_pallas(
                q, k, v, causal=causal, window=window, interpret=_interpret()
            )
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def rglru(x, r, i, lam, h0=None, *, impl=None):
    B, S, C = x.shape
    flops = 6 * B * S * C
    nbytes = 3 * B * S * C * x.dtype.itemsize
    with kernel_span("rglru_scan", (B, S, C), flops, nbytes):
        if _impl(impl) == "pallas":
            from .rglru_scan import rglru_pallas

            return rglru_pallas(x, r, i, lam, h0=h0, interpret=_interpret())
        return _ref.rglru_ref(x, r, i, lam, h0=h0)


def rglru_step(h, x_t, r_t, i_t, lam):
    return _ref.rglru_step_ref(h, x_t, r_t, i_t, lam)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd(x, dt, A_log, Bm, Cm, D, *, chunk: int = 64, state0=None, impl=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    flops = B * S * H * (2 * P * N * 3 + 2 * 64 * P)  # states + intra approx
    nbytes = (x.size + Bm.size * 2) * x.dtype.itemsize * 2
    with kernel_span("ssd_scan", (B, H, S // chunk), flops, nbytes):
        if _impl(impl) == "pallas":
            from .ssd_scan import ssd_pallas

            return ssd_pallas(
                x, dt, A_log, Bm, Cm, D, chunk=chunk, state0=state0, interpret=_interpret()
            )
        return _ref.ssd_ref(x, dt, A_log, Bm, Cm, D, chunk=chunk, state0=state0)


def ssd_step(state, x_t, dt_t, A_log, B_t, C_t, D):
    return _ref.ssd_step_ref(state, x_t, dt_t, A_log, B_t, C_t, D)


def causal_conv1d(x, w, state=None):
    return _ref.causal_conv1d_ref(x, w, state=state)

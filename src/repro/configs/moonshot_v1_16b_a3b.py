"""moonshot-v1-16b-a3b [moe] — kimi/moonlight family, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=163840."""

from repro.models.config import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=163_840,
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408),
        subquadratic=False,
    )

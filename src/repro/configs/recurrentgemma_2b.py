"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000."""

from repro.models.config import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        d_ff=7680,
        vocab_size=256_000,
        head_dim=256,
        mlp_type="geglu",
        norm="rmsnorm",
        tied_embeddings=True,
        rglru=RGLRUConfig(width=2560, d_conv=4, pattern=("rec", "rec", "attn"), local_window=2048),
        subquadratic=True,  # RG-LRU state + 2k local window → runs long_500k
    )

"""whisper-medium [audio] — enc-dec; conv frontend STUBBED (input_specs feeds
precomputed frame embeddings). [arXiv:2212.04356; unverified]
24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865; encoder 24L × 1500 frames."""

from repro.models.config import EncDecConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51_865,
        mlp_type="gelu",
        norm="layernorm",
        tied_embeddings=True,
        encdec=EncDecConfig(enc_layers=24, enc_positions=1500),
        subquadratic=False,
    )

"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified] 48L d_model=2048 vocab=50280 ssm_state=128."""

from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=32,  # unused: attention-free
        num_kv_heads=32,
        d_ff=0,  # unused: no MLP sub-block in Mamba2
        vocab_size=50_280,
        head_dim=64,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256, n_groups=1),
        subquadratic=True,  # O(1) state → runs long_500k
    )

"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``config() -> ModelConfig`` with the exact published
numbers ([source; verified-tier] in the module docstring).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "recurrentgemma-2b",
    "qwen1.5-32b",
    "h2o-danube-1.8b",
    "mistral-large-123b",
    "stablelm-3b",
    "whisper-medium",
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "mamba2-1.3b",
    "llava-next-34b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[arch]}")
    return mod.config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}

"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32_000,
        sliding_window=4096,
        subquadratic=True,  # SWA → O(window) decode, runs long_500k
    )

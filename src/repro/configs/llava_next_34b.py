"""llava-next-34b [vlm] — Yi-34B backbone; anyres tiling frontend STUBBED
(input_specs feeds precomputed patch embeddings, 2880 tokens = 24×24×5 tiles).
[hf:llava-hf/llava-v1.6-*; unverified] 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=20_480,
        vocab_size=64_000,
        head_dim=128,
        rope_theta=5_000_000.0,
        vision_tokens=2880,
        remat="dots",
        subquadratic=False,
    )

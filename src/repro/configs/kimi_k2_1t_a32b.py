"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).
[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) expert
d_ff=2048 vocab=163840, 384 experts top-8.

fsdp=True: at 1T params, weights+optimizer must shard over data×model
(ZeRO-3) to approach the 16 GB/chip HBM budget — see EXPERIMENTS §Dry-run."""

from repro.models.config import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=163_840,
        head_dim=128,
        moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048),
        fsdp=True,
        remat="dots",
        subquadratic=False,
    )

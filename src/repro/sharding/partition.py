"""Logical-axis partitioning: logical names → mesh PartitionSpec.

Every parameter/activation in the model zoo is annotated with *logical axes*
(("layers", "embed", "heads", "head_dim") …).  This module maps them onto the
production mesh ("pod", "data", "model") with divisibility-aware fallback:
if a dimension doesn't divide over the mesh axes of its rule, the rule falls
back to a prefix of those axes (and ultimately replication) rather than
failing to lower — head counts like 40 or 56 simply don't divide a 16-way
model axis, and the correct baseline is replication, not padding (the
hillclimb in EXPERIMENTS §Perf quantifies what padding would buy back).

Sharding modes:
  tp    — Megatron-style: weights sharded over "model" (heads/ffn/vocab/
          experts/channels); batch over ("pod","data").
  fsdp  — ZeRO-3-ish: additionally shards the "embed" dimension of weights
          over ("pod","data"), so parameters and optimizer state scale with
          the full device count (required for the 1T-param kimi config).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axis → mesh axes (tried in order; longest dividing prefix wins)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    # data-parallel axes
    "batch": ("pod", "data"),
    # tensor-parallel axes
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "channels": ("model",),  # RG-LRU / SSM channel dims
    "ssm_heads": ("model",),
    # sequence parallelism (activations only; enabled for long shapes)
    "seq_sp": ("model",),
    # replicated by default
    "embed": (),
    "layers": (),
    "seq": (),
    "head_dim": (),
    "state": (),
    "expert_mlp": (),
    "conv": (),
}

FSDP_OVERRIDES: Dict[str, Tuple[str, ...]] = {
    # ZeRO-3: weight "embed" dims sharded over the data axes too
    "embed": ("pod", "data"),
    "layers": (),
}

SERVE2D_OVERRIDES: Dict[str, Tuple[str, ...]] = {
    # trillion-param MoE serving: weights stay RESIDENT — experts over
    # "model" (rule above) × expert FFN dim over the data axes, so decode
    # moves activations (MBs) instead of FSDP-gathering weights (GBs/step).
    # KV-cache sequence shards over "model" (kv_heads like kimi's 8 can't).
    "expert_mlp": ("pod", "data"),
    "seq": ("model",),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _fit_axes(dim: int, axes: Sequence[str], mesh: Mesh) -> Tuple[str, ...]:
    """Longest prefix of ``axes`` (present in mesh) whose product divides dim."""
    present = [a for a in axes if a in mesh.shape]
    best: Tuple[str, ...] = ()
    prod = 1
    for a in present:
        prod *= _axis_size(mesh, a)
        if prod == 1:
            continue
        if dim % prod == 0:
            best = tuple(present[: present.index(a) + 1])
        else:
            break
    return best


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> PartitionSpec:
    """Map logical axes of one array to a PartitionSpec for ``mesh``."""
    rules = rules or LOGICAL_RULES
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape} rank mismatch")
    used: set = set()
    entries = []
    for ax, dim in zip(axes, shape):
        if ax is None:
            entries.append(None)
            continue
        rule = rules.get(ax)
        if rule is None:
            raise KeyError(f"no partition rule for logical axis {ax!r}")
        fit = tuple(a for a in _fit_axes(dim, rule, mesh) if a not in used)
        # re-check divisibility after removing already-used axes
        prod = int(np.prod([_axis_size(mesh, a) for a in fit])) if fit else 1
        while fit and dim % prod != 0:
            fit = fit[:-1]
            prod = int(np.prod([_axis_size(mesh, a) for a in fit])) if fit else 1
        if not fit:
            entries.append(None)
            continue
        used.update(fit)
        entries.append(fit if len(fit) > 1 else fit[0])
    # trim trailing None for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


@dataclasses.dataclass
class Partitioner:
    """Bound (mesh, mode) partitioning helper used across the framework.

    mode: "tp" (Megatron TP), "fsdp" (ZeRO-3 weights over data axes, for the
    1T train config), "serve2d" (resident 2D expert sharding for MoE decode).
    ``fsdp=True`` is sugar for mode="fsdp".
    """

    mesh: Mesh
    fsdp: bool = False
    mode: str = ""

    def __post_init__(self):
        if not self.mode:
            self.mode = "fsdp" if self.fsdp else "tp"
        self.fsdp = self.mode == "fsdp"

    @property
    def rules(self) -> Dict[str, Tuple[str, ...]]:
        r = dict(LOGICAL_RULES)
        if self.mode == "fsdp":
            r.update(FSDP_OVERRIDES)
        elif self.mode == "serve2d":
            r.update(SERVE2D_OVERRIDES)
        return r

    def pspec(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> PartitionSpec:
        return logical_to_pspec(axes, shape, self.mesh, self.rules)

    def sharding(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(axes, shape))

    def tree_pspecs(self, shapes_tree, axes_tree):
        """Map matching pytrees of shapes and logical-axes tuples to pspecs."""
        return jax.tree_util.tree_map(
            lambda sds, axes: self.pspec(axes, sds.shape),
            shapes_tree,
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
        )

    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    def model_axis_size(self) -> int:
        return _axis_size(self.mesh, "model")

    def dp_size(self) -> int:
        return int(np.prod([_axis_size(self.mesh, a) for a in self.data_axes()]))

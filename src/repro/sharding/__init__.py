from .partition import (  # noqa: F401
    LOGICAL_RULES,
    Partitioner,
    logical_to_pspec,
)
